//! The live training loop over PJRT artifacts (Algorithm 1 realized).
//!
//! ## Step-plan architecture (docs/HOTPATH.md)
//!
//! All per-row bookkeeping that used to be re-derived every step — manifest
//! name formatting, `Segment`/`TpsPlan` clones, tracker-key strings — is
//! now computed **once** in [`StepPlan::build`] when the [`Trainer`] is
//! constructed: executable names resolve to integer [`ExecHandle`]s, row
//! intervals are copied out of the manifest, and every tracker buffer/phase
//! name is interned to a [`BufId`].  `Trainer::step` then walks the
//! prebuilt table performing **zero `format!`/`String` allocations** and,
//! thanks to [`TensorView`], zero input-slab copies.
//!
//! ## Serial vs pipelined execution (docs/SCHEDULER.md)
//!
//! Both paths run against an [`ExecBackend`] (the [`Runtime`] in
//! production).  [`sched::Policy::Serial`] walks the plan row-by-row on
//! the caller's thread with tracker byte accounting — today's default.
//! [`sched::Policy::Pipelined`] lowers the plan once into a row dependency
//! [`Dag`] ([`StepPlan::lower`]) and executes it on a worker pool under
//! memory admission.  Results are **bit-identical**: workers only produce
//! per-row outputs into [`Slot`]s; every floating-point reduction
//! (gradient accumulation, δ-accumulation, H-concat) happens inside a
//! barrier node in exactly the serial loop's order.

use std::sync::Arc;
use std::time::Instant;

use crate::data::SyntheticCorpus;
use crate::error::{Error, Result};
use crate::memory::{BufId, Tracker};
use crate::runtime::manifest::Manifest;
use crate::runtime::{ExecBackend, ExecHandle, Runtime, Tensor, TensorView};
use crate::sched::{self, Dag, ExecOutcome, NodeId, NodeKind, Policy, SchedConfig, Slot, Trace};
use crate::shard::{self, ShardPlan, ShardedExecutor};

use super::{Optimizer, ParamSet};

/// Execution strategy for the live path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// column-centric single-executable step (the paper's Base)
    Base,
    /// OverL-H: segmented halo slabs, checkpoint after pool2
    RowHybrid,
    /// 2PS forward (boundary caches handed between rows) + row-slab BP
    Tps,
    /// broken w/o-sharing ablation (Fig. 11's diverging branch)
    Naive,
}

impl Mode {
    pub fn label(&self) -> &'static str {
        match self {
            Mode::Base => "Base",
            Mode::RowHybrid => "OverL-H",
            Mode::Tps => "2PS",
            Mode::Naive => "naive(w/o sharing)",
        }
    }
}

/// Per-step observability.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub loss: f32,
    /// coordinator-held activation bytes at the step's peak.  Serial: the
    /// tracker's measured ledger.  Pipelined: the admission ledger's peak
    /// of projected per-node + parked handoff bytes (what admission
    /// actually bounds); under sharding, the worst single-device peak.
    pub peak_bytes: u64,
    /// Per-device admission peaks (`vec![peak_bytes]` off the sharded
    /// path).
    pub device_peaks: Vec<u64>,
    pub step_ms: f64,
    /// PJRT executions issued
    pub executions: u64,
}

/// Row extents for the naive equal-split ablation.
///
/// The AOT artifacts are compiled for *equal* slabs (`aot.py` asserts
/// `h % n_rows == 0`), so an uneven split is a planning error — the seed
/// code silently truncated the remainder rows instead, which both
/// under-trained and disagreed with the compiled shapes.
pub fn naive_row_extents(h: usize, n: usize) -> Result<Vec<[usize; 2]>> {
    if n == 0 || h == 0 {
        return Err(Error::InfeasiblePlan(format!(
            "naive split of H={h} into n={n} rows"
        )));
    }
    if h % n != 0 {
        return Err(Error::InfeasiblePlan(format!(
            "naive(w/o sharing) requires n | H: H={h}, n={n} leaves remainder {} — \
             the AOT artifacts are compiled for equal slabs",
            h % n
        )));
    }
    let rh = h / n;
    Ok((0..n).map(|r| [r * rh, (r + 1) * rh]).collect())
}

/// One row of a segment in the prebuilt execution table.
#[derive(Debug, Clone)]
struct RowPlan {
    fwd: ExecHandle,
    bwd: ExecHandle,
    in_iv: [usize; 2],
    out_iv: [usize; 2],
    fp_phase: BufId,   // "fp.{seg}.row{r}"
    bp_phase: BufId,   // "bp.{seg}.row{r}"
    slab_id: BufId,    // "fp.{seg}.slab{r}"
    z_id: BufId,       // "fp.{seg}.z{r}"
    bp_slab_id: BufId, // "bp.{seg}.slab{r}"
}

#[derive(Debug, Clone)]
struct SegPlan {
    param_lo: usize,
    param_hi: usize,
    rows: Vec<RowPlan>,
    out_id: BufId, // "fp.{seg}.out"
}

#[derive(Debug, Clone)]
struct TpsRowPlan {
    fwd: ExecHandle,
    own_iv: [usize; 2],
    phase: BufId,          // "fp.tps.row{r}"
    own_id: BufId,         // "tps.own{r}"
    z_id: BufId,           // "tps.z{r}"
    cache_ids: Vec<BufId>, // "tps.cache{r}.{i}"
}

#[derive(Debug, Clone)]
struct TpsPlan {
    rows: Vec<TpsRowPlan>,
    zl_id: BufId, // "tps.zL"
}

#[derive(Debug, Clone)]
struct BasePlan {
    step: ExecHandle,
    fwd: ExecHandle,
    phase: BufId, // "base.step"
    n_conv: usize,
}

#[derive(Debug, Clone)]
struct HybridPlan {
    segs: Vec<SegPlan>, // [segA (below checkpoint), segB (above)]
    head: ExecHandle,
    head_phase: BufId, // "head"
    dzl_id: BufId,     // "dzL"
    dzck_id: BufId,    // "dzck"
    n_conv: usize,
    /// `Some` for [`Mode::Tps`]: forward runs 2PS over the full depth
    tps: Option<TpsPlan>,
}

#[derive(Debug, Clone)]
struct NaiveRowPlan {
    fwd: ExecHandle,
    bwd: ExecHandle,
    x_iv: [usize; 2],
    z_iv: [usize; 2],
}

#[derive(Debug, Clone)]
struct NaivePlan {
    rows: Vec<NaiveRowPlan>,
    head: ExecHandle,
    fp_phase: BufId, // "naive.fp"
    bp_phase: BufId, // "naive.bp"
    zl_id: BufId,    // "naive.zL"
    n_conv: usize,
}

#[derive(Debug, Clone)]
enum PlanKind {
    Base(BasePlan),
    Hybrid(HybridPlan),
    Naive(NaivePlan),
    /// The naive split is infeasible for this manifest (uneven rows); the
    /// error is surfaced at `step`/`forward` time so `Trainer` construction
    /// for the other modes is unaffected.
    NaiveInfeasible(String),
}

/// Prebuilt execution table for one [`Mode`]: everything `step` needs,
/// resolved once.
#[derive(Debug, Clone)]
pub struct StepPlan {
    kind: PlanKind,
}

impl StepPlan {
    /// Resolve executables, row geometry and tracker IDs for `mode`.
    /// String formatting and name lookup happen here — never in `step`.
    pub fn build(man: &Manifest, mode: Mode, tracker: &mut Tracker) -> Result<StepPlan> {
        let h = |name: &str| -> Result<ExecHandle> { man.index_of(name).map(ExecHandle) };
        let n_conv = man.model.n_conv_params;
        let kind = match mode {
            Mode::Base => PlanKind::Base(BasePlan {
                step: h("base_step")?,
                fwd: h("base_fwd")?,
                phase: tracker.intern("base.step"),
                n_conv,
            }),
            Mode::RowHybrid | Mode::Tps => {
                if man.plan.segments.len() != 2 {
                    return Err(Error::Artifact(format!(
                        "hybrid plan expects 2 segments, manifest has {}",
                        man.plan.segments.len()
                    )));
                }
                let mut segs = Vec::with_capacity(man.plan.segments.len());
                for seg in &man.plan.segments {
                    let mut rows = Vec::with_capacity(seg.rows.len());
                    for (r, row) in seg.rows.iter().enumerate() {
                        rows.push(RowPlan {
                            fwd: h(&format!("{}_row{r}_fwd", seg.name))?,
                            bwd: h(&format!("{}_row{r}_bwd", seg.name))?,
                            in_iv: row.in_iv,
                            out_iv: row.out_iv,
                            fp_phase: tracker.intern(format!("fp.{}.row{r}", seg.name)),
                            bp_phase: tracker.intern(format!("bp.{}.row{r}", seg.name)),
                            slab_id: tracker.intern(format!("fp.{}.slab{r}", seg.name)),
                            z_id: tracker.intern(format!("fp.{}.z{r}", seg.name)),
                            bp_slab_id: tracker.intern(format!("bp.{}.slab{r}", seg.name)),
                        });
                    }
                    segs.push(SegPlan {
                        param_lo: seg.param_lo,
                        param_hi: seg.param_hi,
                        rows,
                        out_id: tracker.intern(format!("fp.{}.out", seg.name)),
                    });
                }
                let tps = if mode == Mode::Tps {
                    let mut rows = Vec::with_capacity(man.plan.tps.rows.len());
                    for (r, row) in man.plan.tps.rows.iter().enumerate() {
                        let fwd = h(&format!("tps_row{r}_fwd"))?;
                        // outputs are [z, caches...]: cache count from the
                        // executable signature, ids interned up front
                        let n_caches =
                            man.executables[fwd.index()].outputs.len().saturating_sub(1);
                        rows.push(TpsRowPlan {
                            fwd,
                            own_iv: row.own_iv,
                            phase: tracker.intern(format!("fp.tps.row{r}")),
                            own_id: tracker.intern(format!("tps.own{r}")),
                            z_id: tracker.intern(format!("tps.z{r}")),
                            cache_ids: (0..n_caches)
                                .map(|i| tracker.intern(format!("tps.cache{r}.{i}")))
                                .collect(),
                        });
                    }
                    Some(TpsPlan {
                        rows,
                        zl_id: tracker.intern("tps.zL"),
                    })
                } else {
                    None
                };
                PlanKind::Hybrid(HybridPlan {
                    segs,
                    head: h("head")?,
                    head_phase: tracker.intern("head"),
                    dzl_id: tracker.intern("dzL"),
                    dzck_id: tracker.intern("dzck"),
                    n_conv,
                    tps,
                })
            }
            Mode::Naive => {
                let n = man.plan.naive_rows;
                let z_h = man.model.heights.last().copied().unwrap_or(0);
                match (
                    naive_row_extents(man.model.h, n),
                    naive_row_extents(z_h, n),
                ) {
                    (Ok(x_ivs), Ok(z_ivs)) => {
                        let mut rows = Vec::with_capacity(n);
                        for r in 0..n {
                            rows.push(NaiveRowPlan {
                                fwd: h(&format!("naive_row{r}_fwd"))?,
                                bwd: h(&format!("naive_row{r}_bwd"))?,
                                x_iv: x_ivs[r],
                                z_iv: z_ivs[r],
                            });
                        }
                        PlanKind::Naive(NaivePlan {
                            rows,
                            head: h("head")?,
                            fp_phase: tracker.intern("naive.fp"),
                            bp_phase: tracker.intern("naive.bp"),
                            zl_id: tracker.intern("naive.zL"),
                            n_conv,
                        })
                    }
                    (Err(e), _) | (_, Err(e)) => PlanKind::NaiveInfeasible(e.to_string()),
                }
            }
        };
        Ok(StepPlan { kind })
    }

    /// Every executable the plan will run — what the trainer warm-compiles
    /// at construction.
    pub fn handles(&self) -> Vec<ExecHandle> {
        let mut out = Vec::new();
        match &self.kind {
            PlanKind::Base(bp) => out.extend([bp.step, bp.fwd]),
            PlanKind::Hybrid(hp) => {
                for seg in &hp.segs {
                    for rp in &seg.rows {
                        out.push(rp.fwd);
                        out.push(rp.bwd);
                    }
                }
                if let Some(tp) = &hp.tps {
                    for rp in &tp.rows {
                        out.push(rp.fwd);
                    }
                }
                out.push(hp.head);
            }
            PlanKind::Naive(np) => {
                for rp in &np.rows {
                    out.push(rp.fwd);
                    out.push(rp.bwd);
                }
                out.push(np.head);
            }
            PlanKind::NaiveInfeasible(_) => {}
        }
        out
    }

    /// Lower the plan into its row dependency DAG (the `sched` tentpole):
    /// no edges between OverL/naive rows, chain edges between consecutive
    /// 2PS rows, barrier nodes at the checkpoint/segment boundaries, the
    /// FP→BP boundary (FC head) and the deterministic reductions.
    ///
    /// Per-node byte estimates come from the manifest executable
    /// signatures (staged input slab + produced outputs; always-resident
    /// parameters ξ excluded) — the admission-control currency.
    ///
    /// Errors with [`Error::InfeasiblePlan`] for a naive-infeasible plan.
    pub fn lower(&self, man: &Manifest) -> Result<PipePlan> {
        let mut dag = Dag::new();
        let mut tasks: Vec<Task> = Vec::new();
        match &self.kind {
            PlanKind::Base(bp) => {
                add(
                    &mut dag,
                    &mut tasks,
                    NodeKind::Row,
                    "base.step".to_string(),
                    vec![],
                    est_fwd(man, bp.step),
                    0, // terminal: its output is the step result, not interim
                    Task::BaseStep,
                );
            }
            PlanKind::Hybrid(hp) => {
                let name_of = |i: usize| -> String {
                    man.plan
                        .segments
                        .get(i)
                        .map(|s| s.name.clone())
                        .unwrap_or_else(|| format!("seg{i}"))
                };
                let (seg0, seg1) = (name_of(0), name_of(1));
                // ---- FP segment A (OverL rows: edge-free) ----
                let fp_a: Vec<NodeId> = hp.segs[0]
                    .rows
                    .iter()
                    .enumerate()
                    .map(|(r, rp)| {
                        add(
                            &mut dag,
                            &mut tasks,
                            NodeKind::Row,
                            format!("fp.{seg0}.row{r}"),
                            vec![],
                            est_fwd(man, rp.fwd),
                            est_out0(man, rp.fwd), // z parked until the ck concat
                            Task::FpRow { seg: 0, row: r },
                        )
                    })
                    .collect();
                let zck_bytes: u64 =
                    hp.segs[0].rows.iter().map(|rp| est_out0(man, rp.fwd)).sum();
                // checkpoint barrier: concat of segment A's rows
                let ck = add(
                    &mut dag,
                    &mut tasks,
                    NodeKind::Barrier,
                    "barrier.ck".to_string(),
                    fp_a,
                    zck_bytes,
                    zck_bytes, // the checkpoint lives until its last reader (segB reduce)
                    Task::CkBarrier,
                );
                // ---- FP upper half: 2PS chain or segment B rows ----
                let (zl_deps, zl_bytes) = match &hp.tps {
                    Some(tp) => {
                        let mut rows: Vec<NodeId> = Vec::with_capacity(tp.rows.len());
                        for (r, rp) in tp.rows.iter().enumerate() {
                            // the weak dependency: row r waits only on row
                            // r−1's boundary-cache handoff
                            let deps = rows.last().map(|&p| vec![p]).unwrap_or_default();
                            let caches_in = if r > 0 {
                                tp.rows[r - 1].cache_ids.len()
                            } else {
                                0
                            };
                            rows.push(add(
                                &mut dag,
                                &mut tasks,
                                NodeKind::TpsRow,
                                format!("fp.tps.row{r}"),
                                deps,
                                est_tps(man, rp.fwd, caches_in),
                                // z + boundary caches parked until consumed
                                est_outs(man, rp.fwd),
                                Task::TpsRow { row: r },
                            ));
                        }
                        let bytes: u64 =
                            tp.rows.iter().map(|rp| est_out0(man, rp.fwd)).sum();
                        // zL depends on *every* row (the concat consumes
                        // every z slab), not just the chain tail — the
                        // extra edges are transitively implied, but they
                        // make the DAG's consumer structure match the data
                        // flow so parked z grants release at the concat
                        (rows, bytes)
                    }
                    None => {
                        let ids: Vec<NodeId> = hp.segs[1]
                            .rows
                            .iter()
                            .enumerate()
                            .map(|(r, rp)| {
                                add(
                                    &mut dag,
                                    &mut tasks,
                                    NodeKind::Row,
                                    format!("fp.{seg1}.row{r}"),
                                    vec![ck],
                                    est_fwd(man, rp.fwd),
                                    est_out0(man, rp.fwd), // z parked until zL
                                    Task::FpRow { seg: 1, row: r },
                                )
                            })
                            .collect();
                        let bytes: u64 =
                            hp.segs[1].rows.iter().map(|rp| est_out0(man, rp.fwd)).sum();
                        (ids, bytes)
                    }
                };
                let zl = add(
                    &mut dag,
                    &mut tasks,
                    NodeKind::Barrier,
                    "barrier.zL".to_string(),
                    zl_deps,
                    zl_bytes,
                    zl_bytes, // z^L parked until the head consumes it
                    Task::ZlBarrier,
                );
                // FP→BP boundary: the FC head
                let head = add(
                    &mut dag,
                    &mut tasks,
                    NodeKind::Barrier,
                    "head".to_string(),
                    vec![zl],
                    est_fwd(man, hp.head),
                    // loss + dzL + head grads parked until the segB reduce
                    est_outs(man, hp.head),
                    Task::Head,
                );
                // ---- BP segment B rows (independent given head + ck) ----
                let bp_b: Vec<NodeId> = hp.segs[1]
                    .rows
                    .iter()
                    .enumerate()
                    .map(|(r, rp)| {
                        add(
                            &mut dag,
                            &mut tasks,
                            NodeKind::Row,
                            format!("bp.{seg1}.row{r}"),
                            vec![head, ck],
                            est_bwd(man, rp.bwd),
                            est_outs(man, rp.bwd), // row grads + dx parked until reduce
                            Task::BpRowB { row: r },
                        )
                    })
                    .collect();
                let mut red_b_deps = bp_b;
                red_b_deps.extend([head, ck]);
                let red_b = add(
                    &mut dag,
                    &mut tasks,
                    NodeKind::Barrier,
                    format!("barrier.bp.{seg1}"),
                    red_b_deps,
                    zck_bytes, // dz_ck accumulator
                    zck_bytes, // dz_ck parked until the segA rows consume it
                    Task::ReduceB,
                );
                // ---- BP segment A rows ----
                let bp_a: Vec<NodeId> = hp.segs[0]
                    .rows
                    .iter()
                    .enumerate()
                    .map(|(r, rp)| {
                        add(
                            &mut dag,
                            &mut tasks,
                            NodeKind::Row,
                            format!("bp.{seg0}.row{r}"),
                            vec![red_b],
                            est_bwd(man, rp.bwd),
                            est_outs(man, rp.bwd), // row grads parked until reduce
                            Task::BpRowA { row: r },
                        )
                    })
                    .collect();
                let mut red_a_deps = bp_a;
                red_a_deps.push(red_b);
                add(
                    &mut dag,
                    &mut tasks,
                    NodeKind::Barrier,
                    format!("barrier.bp.{seg0}"),
                    red_a_deps,
                    0,
                    0, // terminal
                    Task::ReduceA,
                );
            }
            PlanKind::Naive(np) => {
                let fp: Vec<NodeId> = np
                    .rows
                    .iter()
                    .enumerate()
                    .map(|(r, rp)| {
                        add(
                            &mut dag,
                            &mut tasks,
                            NodeKind::Row,
                            format!("naive.fp.row{r}"),
                            vec![],
                            est_fwd(man, rp.fwd),
                            est_out0(man, rp.fwd), // z parked until the zL concat
                            Task::NaiveFp { row: r },
                        )
                    })
                    .collect();
                let zl_bytes: u64 = np.rows.iter().map(|rp| est_out0(man, rp.fwd)).sum();
                let zl = add(
                    &mut dag,
                    &mut tasks,
                    NodeKind::Barrier,
                    "barrier.naive.zL".to_string(),
                    fp,
                    zl_bytes,
                    zl_bytes, // z^L parked until the head consumes it
                    Task::NaiveZl,
                );
                let head = add(
                    &mut dag,
                    &mut tasks,
                    NodeKind::Barrier,
                    "naive.head".to_string(),
                    vec![zl],
                    est_fwd(man, np.head),
                    est_outs(man, np.head), // loss + dzL + head grads until reduce
                    Task::NaiveHead,
                );
                let bp: Vec<NodeId> = np
                    .rows
                    .iter()
                    .enumerate()
                    .map(|(r, rp)| {
                        add(
                            &mut dag,
                            &mut tasks,
                            NodeKind::Row,
                            format!("naive.bp.row{r}"),
                            vec![head],
                            est_bwd(man, rp.bwd),
                            est_outs(man, rp.bwd), // row grads parked until reduce
                            Task::NaiveBp { row: r },
                        )
                    })
                    .collect();
                let mut deps = bp;
                deps.push(head);
                add(
                    &mut dag,
                    &mut tasks,
                    NodeKind::Barrier,
                    "barrier.naive.reduce".to_string(),
                    deps,
                    0,
                    0, // terminal
                    Task::NaiveReduce,
                );
            }
            PlanKind::NaiveInfeasible(msg) => {
                return Err(Error::InfeasiblePlan(msg.clone()));
            }
        }
        debug_assert_eq!(dag.len(), tasks.len());
        Ok(PipePlan { dag, tasks })
    }
}

/// What a DAG node does — the executor's `NodeId` indexes both
/// `PipePlan::dag` and `PipePlan::tasks`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Task {
    BaseStep,
    FpRow { seg: usize, row: usize },
    CkBarrier,
    TpsRow { row: usize },
    ZlBarrier,
    Head,
    BpRowB { row: usize },
    ReduceB,
    BpRowA { row: usize },
    ReduceA,
    NaiveFp { row: usize },
    NaiveZl,
    NaiveHead,
    NaiveBp { row: usize },
    NaiveReduce,
}

/// A [`StepPlan`] lowered to its row dependency DAG plus the node→work
/// mapping the pipelined step executes.
#[derive(Debug, Clone)]
pub struct PipePlan {
    dag: Dag,
    tasks: Vec<Task>,
}

impl PipePlan {
    pub fn dag(&self) -> &Dag {
        &self.dag
    }
}

fn add(
    dag: &mut Dag,
    tasks: &mut Vec<Task>,
    kind: NodeKind,
    label: String,
    deps: Vec<NodeId>,
    est_bytes: u64,
    out_bytes: u64,
    task: Task,
) -> NodeId {
    tasks.push(task);
    dag.push_out(kind, label, deps, est_bytes, out_bytes)
}

fn shape_bytes(shape: &[usize]) -> u64 {
    (shape.iter().product::<usize>() * 4) as u64
}

/// Projected bytes of a forward-style node: staged input slab + outputs.
fn est_fwd(man: &Manifest, h: ExecHandle) -> u64 {
    man.executables
        .get(h.index())
        .map(|e| {
            let slab = e.inputs.first().map(|s| shape_bytes(s)).unwrap_or(0);
            let outs: u64 = e.outputs.iter().map(|s| shape_bytes(s)).sum();
            slab + outs
        })
        .unwrap_or(0)
}

/// Projected bytes of a 2PS row: own slab + the boundary caches staged
/// from the predecessor row + outputs (z + this row's caches).  The cache
/// inputs sit between the slab and the parameters in the signature, so
/// counting only `in0` (as [`est_fwd`] does) would hide exactly the bytes
/// the 2PS chain exists to manage from admission control.
fn est_tps(man: &Manifest, h: ExecHandle, caches_in: usize) -> u64 {
    man.executables
        .get(h.index())
        .map(|e| {
            let staged: u64 = e
                .inputs
                .iter()
                .take(1 + caches_in)
                .map(|s| shape_bytes(s))
                .sum();
            let outs: u64 = e.outputs.iter().map(|s| shape_bytes(s)).sum();
            staged + outs
        })
        .unwrap_or(0)
}

/// Projected bytes of a backward-style node: slab + δ slice + outputs.
fn est_bwd(man: &Manifest, h: ExecHandle) -> u64 {
    man.executables
        .get(h.index())
        .map(|e| {
            let slab = e.inputs.first().map(|s| shape_bytes(s)).unwrap_or(0);
            let dz = if e.inputs.len() >= 2 {
                e.inputs.last().map(|s| shape_bytes(s)).unwrap_or(0)
            } else {
                0
            };
            let outs: u64 = e.outputs.iter().map(|s| shape_bytes(s)).sum();
            slab + dz + outs
        })
        .unwrap_or(0)
}

/// Bytes of an executable's first output (a row's z slab — what survives
/// into the concat barrier).
fn est_out0(man: &Manifest, h: ExecHandle) -> u64 {
    man.executables
        .get(h.index())
        .and_then(|e| e.outputs.first())
        .map(|s| shape_bytes(s))
        .unwrap_or(0)
}

/// Total output bytes of an executable — what sits parked in handoff
/// slots between the node's finish and its last consumer's finish (the
/// `Node::out_bytes` currency the admission ledger retains).
fn est_outs(man: &Manifest, h: ExecHandle) -> u64 {
    man.executables
        .get(h.index())
        .map(|e| e.outputs.iter().map(|s| shape_bytes(s)).sum())
        .unwrap_or(0)
}

/// Sharded execution state: the transfer-lowered plan plus the
/// persistent worker pool (constructed once in [`Trainer::set_sched`],
/// reused by every step — no spawn-per-step).
pub struct ShardState {
    plan: ShardPlan,
    exec: ShardedExecutor,
}

impl ShardState {
    /// Build the sharded execution state for one lowered plan: the
    /// (possibly heterogeneous) `shard::Topology` from the config's
    /// device specs, per-device admission budgets clamped to what each device
    /// can actually hold (`min(cfg.mem_budget, usable HBM − ξ)` where ξ
    /// is the always-resident parameter + optimizer bytes), the
    /// partition + transfer lowering, and the persistent worker pool.
    ///
    /// Errors — leaving nothing half-built — when the partition is
    /// infeasible under the clamped ledgers **or** any device's
    /// serial-order replay peak exceeds its clamped budget: a plan that
    /// passes admission but overflows a small device's memory would OOM
    /// on real hardware, so it is rejected here, at configuration time.
    pub fn build(pipe: &PipePlan, cfg: &SchedConfig, xi: u64) -> Result<ShardState> {
        let sc = cfg.shard.clone().unwrap_or_else(|| shard::ShardConfig::new(1));
        let topo = sc.topology();
        let budgets: Vec<u64> = topo
            .budgets(xi)
            .into_iter()
            .map(|cap| cap.min(cfg.mem_budget))
            .collect();
        let plan = ShardPlan::build(pipe.dag(), &topo, sc.policy, budgets)?;
        plan.check_budgets()?;
        Ok(ShardState {
            plan,
            exec: ShardedExecutor::new(cfg.workers),
        })
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }
}

/// Scheduler state carried by the trainer: the active [`SchedConfig`]
/// plus the sharded execution state built for it.  Reconfiguration is
/// **transactional**: [`SchedState::set`] performs every fallible step
/// before touching a field, so a failed reconfiguration leaves the
/// previous (working) configuration fully in place — the trainer never
/// reports pipelined while stepping serially.
struct SchedState {
    cfg: SchedConfig,
    shard: Option<ShardState>,
}

impl SchedState {
    fn new() -> SchedState {
        SchedState {
            cfg: SchedConfig::default(),
            shard: None,
        }
    }

    /// Swap in `cfg`, building the sharded state for a pipelined policy.
    /// `pipe` is the trainer's lowered DAG (`None` when the plan was
    /// never lowered — a naive-infeasible manifest), `xi` the
    /// always-resident bytes.  On `Err` no field has changed.
    fn set(&mut self, pipe: Option<&PipePlan>, cfg: SchedConfig, xi: u64) -> Result<()> {
        let shard = match cfg.policy {
            Policy::Serial => None,
            Policy::Pipelined => {
                let pipe = pipe.ok_or_else(|| {
                    Error::Sched(
                        "cannot switch to pipelined execution: the step plan was never \
                         lowered (naive split infeasible for this manifest)"
                            .into(),
                    )
                })?;
                Some(ShardState::build(pipe, &cfg, xi)?)
            }
        };
        self.cfg = cfg;
        self.shard = shard;
        Ok(())
    }
}

/// Row-centric trainer over an artifact bundle.
pub struct Trainer<'r> {
    pub rt: &'r Runtime,
    pub params: ParamSet,
    pub optimizer: Optimizer,
    /// Fixed at construction: the [`StepPlan`] is built for this mode, so
    /// the field is read-only (swapping modes means a new `Trainer`).
    mode: Mode,
    pub tracker: Tracker,
    plan: StepPlan,
    /// Row scheduler configuration + sharded execution state
    /// ([`Policy::Serial`], no shard, by default).  The shard half is
    /// `Some` exactly when the policy is pipelined (one stock device
    /// unless `SchedConfig::shard` says otherwise) — [`SchedState::set`]
    /// keeps the pair consistent transactionally.
    sched: SchedState,
    /// The plan's lowered DAG (`None` only for a naive-infeasible plan).
    pipe: Option<PipePlan>,
    /// Event trace of the most recent pipelined step (per-device lanes
    /// via `TraceEvent::device`).
    last_trace: Option<Trace>,
}

impl<'r> Trainer<'r> {
    pub fn new(rt: &'r Runtime, mode: Mode, lr: f32, seed: u64) -> Result<Trainer<'r>> {
        Trainer::with_optimizer(rt, mode, Optimizer::sgd(lr), seed)
    }

    /// Use a stateful optimizer (momentum/Adam); its state bytes belong to
    /// ξ in the planners' accounting (`Optimizer::state_bytes`).
    ///
    /// Builds the mode's [`StepPlan`] here — executable resolution, row
    /// geometry, tracker-ID interning and the DAG lowering all happen
    /// once, not per step.
    pub fn with_optimizer(
        rt: &'r Runtime,
        mode: Mode,
        optimizer: Optimizer,
        seed: u64,
    ) -> Result<Trainer<'r>> {
        let params = ParamSet::init(&rt.manifest.model, seed);
        let mut tracker = Tracker::new();
        let plan = StepPlan::build(&rt.manifest, mode, &mut tracker)?;
        let pipe = match &plan.kind {
            PlanKind::NaiveInfeasible(_) => None,
            _ => Some(plan.lower(&rt.manifest)?),
        };
        // warm start: compile every executable the plan references now, so
        // no step (and no step timing) ever includes a first-use compile
        for h in plan.handles() {
            rt.ensure_compiled_h(h)?;
        }
        Ok(Trainer {
            rt,
            params,
            optimizer,
            mode,
            tracker,
            plan,
            sched: SchedState::new(),
            pipe,
            last_trace: None,
        })
    }

    /// The execution mode the step plan was built for.
    pub fn mode(&self) -> Mode {
        self.mode
    }

    /// Switch between serial and pipelined/sharded row execution.
    ///
    /// For [`Policy::Pipelined`] this builds the sharded execution state
    /// once — the real `shard::Topology` from `cfg.shard`'s device specs
    /// (mixed RTX 3090 / A100 / capacity-scaled topologies are first
    /// class), the partition, the transfer lowering (identity on one
    /// device) and the **persistent** worker pool every subsequent step
    /// reuses.  Each device's admission-ledger budget is
    /// `min(cfg.mem_budget, usable HBM − ξ)` for *that* device
    /// (`Topology::budgets`), and the plan is rejected up front when
    /// any device's serial-order replay peak exceeds its clamped budget.
    ///
    /// Fallible and **transactional**: on error — including asking for a
    /// pipelined policy when the step plan could never be lowered — the
    /// trainer keeps its previous (working) configuration in full.
    pub fn set_sched(&mut self, cfg: SchedConfig) -> Result<()> {
        let xi = self.params.size_bytes() + self.optimizer.state_bytes(&self.params);
        self.sched.set(self.pipe.as_ref(), cfg, xi)?;
        // a prior step's trace belongs to the previous plan's DAG; keeping
        // it would let trace_json pair it with the new one
        self.last_trace = None;
        Ok(())
    }

    pub fn sched(&self) -> &SchedConfig {
        &self.sched.cfg
    }

    /// The lowered row dependency DAG (for inspection/attribution).
    pub fn pipe_plan(&self) -> Option<&PipePlan> {
        self.pipe.as_ref()
    }

    /// The sharded plan (partition, transfers, per-device budgets) when
    /// the policy is pipelined.
    pub fn shard_state(&self) -> Option<&ShardState> {
        self.sched.shard.as_ref()
    }

    /// Per-row event trace of the most recent pipelined step, with
    /// per-device lanes in `TraceEvent::device`.
    pub fn last_trace(&self) -> Option<&Trace> {
        self.last_trace.as_ref()
    }

    /// Attribution JSON of the most recent pipelined step (per-device
    /// lanes + `Transfer` spans) — what `--trace-out` writes.
    pub fn trace_json(&self) -> Option<String> {
        let trace = self.last_trace.as_ref()?;
        let dag = match &self.sched.shard {
            Some(ss) => ss.plan.dag(),
            None => self.pipe.as_ref()?.dag(),
        };
        Some(trace.to_json(dag))
    }

    /// One training step on (x, y); returns the loss.
    pub fn step(&mut self, x: &Tensor, y1h: &Tensor) -> Result<StepStats> {
        let t0 = Instant::now();
        let exec0 = self.rt.stats().executions;
        // activation buffers are strictly per-step; start a fresh ledger
        // (the interner survives — plan BufIds stay valid)
        self.tracker.reset();
        let pipelined = self.sched.cfg.policy == Policy::Pipelined;
        let (loss, grads, peak_bytes, device_peaks) = if pipelined {
            let pipe = match (&self.plan.kind, &self.pipe) {
                (PlanKind::NaiveInfeasible(msg), _) => {
                    return Err(Error::InfeasiblePlan(msg.clone()))
                }
                (_, Some(p)) => p,
                (_, None) => return Err(Error::Sched("step plan was never lowered".into())),
            };
            let (loss, grads, outcome) = Self::step_pipelined(
                self.rt,
                &self.plan,
                pipe,
                &self.params,
                &self.sched.cfg,
                self.sched.shard.as_ref(),
                x,
                y1h,
            )?;
            let peak = outcome.peak_bytes;
            let device_peaks = outcome.device_peaks.clone();
            self.last_trace = Some(outcome.trace);
            (loss, grads, peak, device_peaks)
        } else {
            let (loss, grads) = match &self.plan.kind {
                PlanKind::Base(bp) => {
                    Self::step_base(self.rt, &self.params, &mut self.tracker, bp, x, y1h)?
                }
                PlanKind::Hybrid(hp) => {
                    Self::step_hybrid(self.rt, &self.params, &mut self.tracker, hp, x, y1h)?
                }
                PlanKind::Naive(np) => {
                    Self::step_naive(self.rt, &self.params, &mut self.tracker, np, x, y1h)?
                }
                PlanKind::NaiveInfeasible(msg) => {
                    return Err(Error::InfeasiblePlan(msg.clone()))
                }
            };
            let peak = self.tracker.peak();
            (loss, grads, peak, vec![peak])
        };
        self.optimizer.step(&mut self.params, &grads)?;
        Ok(StepStats {
            loss,
            peak_bytes,
            device_peaks,
            step_ms: t0.elapsed().as_secs_f64() * 1e3,
            executions: self.rt.stats().executions - exec0,
        })
    }

    /// Forward-only pass producing z^L (used by tests + quickstart).
    pub fn forward(&mut self, x: &Tensor) -> Result<Tensor> {
        self.tracker.reset();
        match &self.plan.kind {
            PlanKind::Base(bp) => {
                let mut args: Vec<TensorView> = Vec::with_capacity(1 + bp.n_conv);
                args.push(x.view());
                args.extend(self.params.tensors[..bp.n_conv].iter().map(|t| t.view()));
                Ok(self.rt.execute_h(bp.fwd, &args)?.remove(0))
            }
            PlanKind::Hybrid(hp) => match &hp.tps {
                Some(tp) => {
                    Self::tps_fp(self.rt, &self.params, &mut self.tracker, tp, hp.n_conv, x)
                }
                None => {
                    let zck = Self::segment_fp(
                        self.rt,
                        &self.params,
                        &mut self.tracker,
                        &hp.segs[0],
                        x,
                    )?;
                    Self::segment_fp(self.rt, &self.params, &mut self.tracker, &hp.segs[1], &zck)
                }
            },
            PlanKind::Naive(np) => Self::naive_fp(self.rt, &self.params, np, x),
            PlanKind::NaiveInfeasible(msg) => Err(Error::InfeasiblePlan(msg.clone())),
        }
    }

    // ---------------- Base ----------------

    fn step_base(
        ex: &dyn ExecBackend,
        params: &ParamSet,
        tracker: &mut Tracker,
        bp: &BasePlan,
        x: &Tensor,
        y1h: &Tensor,
    ) -> Result<(f32, Vec<Tensor>)> {
        tracker.mark_id(bp.phase);
        let mut args: Vec<TensorView> = Vec::with_capacity(2 + params.tensors.len());
        args.push(x.view());
        args.push(y1h.view());
        args.extend(params.tensors.iter().map(|t| t.view()));
        let mut out = ex.exec(bp.step, &args)?;
        let grads = out.split_off(1);
        let loss = out[0].data[0];
        Ok((loss, grads))
    }

    // ---------------- OverL-H (and 2PS-fwd variant) ----------------

    /// FP of one segment, row by row; returns the concatenated output.
    fn segment_fp(
        ex: &dyn ExecBackend,
        params: &ParamSet,
        tracker: &mut Tracker,
        seg: &SegPlan,
        input: &Tensor,
    ) -> Result<Tensor> {
        let seg_params = &params.tensors[seg.param_lo..seg.param_hi];
        let mut rows: Vec<Tensor> = Vec::with_capacity(seg.rows.len());
        for rp in &seg.rows {
            tracker.mark_id(rp.fp_phase);
            // zero-copy: a strided view, gathered only at the literal boundary
            let slab = input.slice_h(rp.in_iv[0], rp.in_iv[1])?;
            tracker.alloc_id(rp.slab_id, slab.size_bytes());
            let z = {
                let mut args: Vec<TensorView> = Vec::with_capacity(1 + seg_params.len());
                args.push(slab);
                args.extend(seg_params.iter().map(|t| t.view()));
                ex.exec(rp.fwd, &args)?.remove(0)
            };
            tracker.alloc_id(rp.z_id, z.size_bytes());
            // the input slab is released as soon as the row is done —
            // the row-centric memory reuse (Algorithm 1 line 9)
            tracker.free_id(rp.slab_id)?;
            rows.push(z);
        }
        let out = {
            let views: Vec<TensorView> = rows.iter().map(|t| t.view()).collect();
            Tensor::concat_h(&views)?
        };
        tracker.alloc_id(seg.out_id, out.size_bytes());
        for rp in &seg.rows {
            tracker.free_id(rp.z_id)?;
        }
        Ok(out)
    }

    /// 2PS forward over the full depth (N = tps_rows), caches handed
    /// row-to-row exactly as §IV-A describes.
    fn tps_fp(
        ex: &dyn ExecBackend,
        params: &ParamSet,
        tracker: &mut Tracker,
        tp: &TpsPlan,
        n_conv: usize,
        x: &Tensor,
    ) -> Result<Tensor> {
        let conv = &params.tensors[..n_conv];
        let mut rows: Vec<Tensor> = Vec::with_capacity(tp.rows.len());
        let mut caches: Vec<Tensor> = Vec::new();
        for (r, rp) in tp.rows.iter().enumerate() {
            tracker.mark_id(rp.phase);
            let own = x.slice_h(rp.own_iv[0], rp.own_iv[1])?;
            tracker.alloc_id(rp.own_id, own.size_bytes());
            let mut out = {
                let mut args: Vec<TensorView> =
                    Vec::with_capacity(1 + caches.len() + conv.len());
                args.push(own);
                args.extend(caches.iter().map(|t| t.view())); // from row r−1
                args.extend(conv.iter().map(|t| t.view()));
                ex.exec(rp.fwd, &args)?
            };
            let z = out.remove(0);
            // free consumed caches, keep newly produced ones
            if r > 0 {
                for id in &tp.rows[r - 1].cache_ids {
                    tracker.free_id(*id)?;
                }
            }
            caches = out;
            debug_assert_eq!(caches.len(), rp.cache_ids.len());
            for (id, c) in rp.cache_ids.iter().zip(&caches) {
                tracker.alloc_id(*id, c.size_bytes());
            }
            tracker.alloc_id(rp.z_id, z.size_bytes());
            tracker.free_id(rp.own_id)?;
            rows.push(z);
        }
        if let Some(last) = tp.rows.last() {
            for id in &last.cache_ids {
                tracker.free_id(*id)?;
            }
        }
        let z_l = {
            let views: Vec<TensorView> = rows.iter().map(|t| t.view()).collect();
            Tensor::concat_h(&views)?
        };
        tracker.alloc_id(tp.zl_id, z_l.size_bytes());
        for rp in &tp.rows {
            tracker.free_id(rp.z_id)?;
        }
        Ok(z_l)
    }

    /// Shared head + row-wise BP for the hybrid and 2PS modes.
    fn step_hybrid(
        ex: &dyn ExecBackend,
        params: &ParamSet,
        tracker: &mut Tracker,
        hp: &HybridPlan,
        x: &Tensor,
        y1h: &Tensor,
    ) -> Result<(f32, Vec<Tensor>)> {
        let seg_a = &hp.segs[0];
        let seg_b = &hp.segs[1];
        // ---- FP ----
        let zck = Self::segment_fp(ex, params, tracker, seg_a, x)?; // checkpoint
        let (z_l, zl_id) = match &hp.tps {
            // 2PS forward recomputes from the input; the checkpoint is
            // still produced for BP (2PS-H keeps checkpoints too)
            Some(tp) => (Self::tps_fp(ex, params, tracker, tp, hp.n_conv, x)?, tp.zl_id),
            None => (
                Self::segment_fp(ex, params, tracker, seg_b, &zck)?,
                seg_b.out_id,
            ),
        };
        // ---- head ----
        tracker.mark_id(hp.head_phase);
        let loss_out = ex.exec(
            hp.head,
            &[
                z_l.view(),
                y1h.view(),
                params.tensors[hp.n_conv].view(),
                params.tensors[hp.n_conv + 1].view(),
            ],
        )?;
        let loss = loss_out[0].data[0];
        let dz_l = &loss_out[1];
        tracker.alloc_id(hp.dzl_id, dz_l.size_bytes());
        // z^L consumed by the head
        tracker.free_id(zl_id)?;

        let mut grads = params.grad_zeros();
        let n_conv = hp.n_conv;
        grads[n_conv] = loss_out[2].clone(); // dWfc
        grads[n_conv + 1] = loss_out[3].clone(); // dbfc

        // ---- BP segment B (rows reversed; recompute inside row_bwd) ----
        let seg_b_params = &params.tensors[seg_b.param_lo..seg_b.param_hi];
        let mut dz_ck = Tensor::zeros(&zck.shape);
        tracker.alloc_id(hp.dzck_id, dz_ck.size_bytes());
        for rp in seg_b.rows.iter().rev() {
            tracker.mark_id(rp.bp_phase);
            let slab = zck.slice_h(rp.in_iv[0], rp.in_iv[1])?;
            let dz = dz_l.slice_h(rp.out_iv[0], rp.out_iv[1])?;
            tracker.alloc_id(rp.bp_slab_id, slab.size_bytes() + dz.size_bytes());
            let mut out = {
                let mut args: Vec<TensorView> = Vec::with_capacity(2 + seg_b_params.len());
                args.push(slab);
                args.extend(seg_b_params.iter().map(|t| t.view()));
                args.push(dz);
                ex.exec(rp.bwd, &args)?
            };
            let _z = out.pop().expect("bwd returns recomputed z last");
            let dx = out.pop().expect("segB bwd returns dx before z");
            for (i, g) in out.into_iter().enumerate() {
                grads[seg_b.param_lo + i].axpy(1.0, &g)?;
            }
            // overlapping slab input-gradients accumulate by linearity
            dz_ck.add_h(rp.in_iv[0], &dx)?;
            tracker.free_id(rp.bp_slab_id)?;
        }
        tracker.free_id(hp.dzl_id)?;

        // ---- BP segment A ----
        let seg_a_params = &params.tensors[seg_a.param_lo..seg_a.param_hi];
        for rp in seg_a.rows.iter().rev() {
            tracker.mark_id(rp.bp_phase);
            let slab = x.slice_h(rp.in_iv[0], rp.in_iv[1])?;
            let dz = dz_ck.slice_h(rp.out_iv[0], rp.out_iv[1])?;
            tracker.alloc_id(rp.bp_slab_id, slab.size_bytes() + dz.size_bytes());
            let mut out = {
                let mut args: Vec<TensorView> = Vec::with_capacity(2 + seg_a_params.len());
                args.push(slab);
                args.extend(seg_a_params.iter().map(|t| t.view()));
                args.push(dz);
                ex.exec(rp.bwd, &args)?
            };
            out.pop().expect("bwd returns recomputed z last");
            for (i, g) in out.into_iter().enumerate() {
                grads[seg_a.param_lo + i].axpy(1.0, &g)?;
            }
            tracker.free_id(rp.bp_slab_id)?;
        }
        tracker.free_id(hp.dzck_id)?;
        tracker.free_id(seg_a.out_id)?; // checkpoint consumed
        Ok((loss, grads))
    }

    // ---------------- naive (w/o sharing) ----------------

    /// Naive FP does no per-row tracking (seed parity: the ablation only
    /// accounts at the step level), hence no tracker parameter.
    fn naive_fp(
        ex: &dyn ExecBackend,
        params: &ParamSet,
        np: &NaivePlan,
        x: &Tensor,
    ) -> Result<Tensor> {
        let conv = &params.tensors[..np.n_conv];
        let mut rows = Vec::with_capacity(np.rows.len());
        for rp in &np.rows {
            let slab = x.slice_h(rp.x_iv[0], rp.x_iv[1])?;
            let mut args: Vec<TensorView> = Vec::with_capacity(1 + conv.len());
            args.push(slab);
            args.extend(conv.iter().map(|t| t.view()));
            rows.push(ex.exec(rp.fwd, &args)?.remove(0));
        }
        let views: Vec<TensorView> = rows.iter().map(|t| t.view()).collect();
        Tensor::concat_h(&views)
    }

    fn step_naive(
        ex: &dyn ExecBackend,
        params: &ParamSet,
        tracker: &mut Tracker,
        np: &NaivePlan,
        x: &Tensor,
        y1h: &Tensor,
    ) -> Result<(f32, Vec<Tensor>)> {
        tracker.mark_id(np.fp_phase);
        let z_l = Self::naive_fp(ex, params, np, x)?;
        tracker.alloc_id(np.zl_id, z_l.size_bytes());
        let loss_out = ex.exec(
            np.head,
            &[
                z_l.view(),
                y1h.view(),
                params.tensors[np.n_conv].view(),
                params.tensors[np.n_conv + 1].view(),
            ],
        )?;
        let loss = loss_out[0].data[0];
        let dz_l = &loss_out[1];
        let mut grads = params.grad_zeros();
        grads[np.n_conv] = loss_out[2].clone();
        grads[np.n_conv + 1] = loss_out[3].clone();
        tracker.mark_id(np.bp_phase);
        let conv_n = np.n_conv;
        for rp in np.rows.iter().rev() {
            let slab = x.slice_h(rp.x_iv[0], rp.x_iv[1])?;
            let dz = dz_l.slice_h(rp.z_iv[0], rp.z_iv[1])?;
            let mut out = {
                let mut args: Vec<TensorView> = Vec::with_capacity(2 + conv_n);
                args.push(slab);
                args.extend(params.tensors[..conv_n].iter().map(|t| t.view()));
                args.push(dz);
                ex.exec(rp.bwd, &args)?
            };
            out.pop().expect("bwd returns recomputed z last");
            for (i, g) in out.into_iter().enumerate() {
                grads[i].axpy(1.0, &g)?;
            }
        }
        tracker.free_id(np.zl_id)?;
        Ok((loss, grads))
    }

    // ---------------- pipelined path (docs/SCHEDULER.md) ----------------

    /// Execute one step over the lowered DAG on a worker pool — the
    /// per-step `sched::run` scope without sharding, or the persistent
    /// [`ShardedExecutor`] (per-device ledgers, transfer nodes) when a
    /// [`ShardState`] is supplied.  Bit-exact with the serial path either
    /// way: every reduction happens in a barrier node in the serial
    /// loop's order; workers only produce per-row outputs, and transfers
    /// carry data, not arithmetic.
    fn step_pipelined(
        ex: &dyn ExecBackend,
        plan: &StepPlan,
        pipe: &PipePlan,
        params: &ParamSet,
        cfg: &SchedConfig,
        shard: Option<&ShardState>,
        x: &Tensor,
        y1h: &Tensor,
    ) -> Result<(f32, Vec<Tensor>, ExecOutcome)> {
        // run a node-task closure on whichever executor is configured;
        // both call it with *base* DAG node ids
        let drive = |runner: &(dyn Fn(NodeId) -> Result<()> + Sync)| match shard {
            Some(ss) => ss.exec.run_step(&ss.plan, runner),
            None => sched::run(&pipe.dag, cfg, runner),
        };
        match &plan.kind {
            PlanKind::Base(bp) => {
                let out: Slot<(f32, Vec<Tensor>)> = Slot::new();
                let outcome = drive(&|n| match pipe.tasks[n] {
                    Task::BaseStep => pipe_base(ex, params, bp, x, y1h, &out),
                    t => Err(Error::Sched(format!("task {t:?} in base step"))),
                })?;
                let (loss, grads) = out.take("base.out")?;
                Ok((loss, grads, outcome))
            }
            PlanKind::Hybrid(hp) => {
                let cells = HybridCells::new(hp);
                let outcome = drive(&|n| {
                    run_hybrid_task(ex, params, hp, x, y1h, &cells, pipe.tasks[n])
                })?;
                let (loss, grads) = cells.out.take("out")?;
                Ok((loss, grads, outcome))
            }
            PlanKind::Naive(np) => {
                let cells = NaiveCells::new(np);
                let outcome = drive(&|n| {
                    run_naive_task(ex, params, np, x, y1h, &cells, pipe.tasks[n])
                })?;
                let (loss, grads) = cells.out.take("out")?;
                Ok((loss, grads, outcome))
            }
            PlanKind::NaiveInfeasible(msg) => Err(Error::InfeasiblePlan(msg.clone())),
        }
    }
}

// ---------------- pipelined node handlers ----------------
//
// Free functions rather than methods: they run on scheduler worker
// threads and share nothing but `&` references (ExecBackend is `Sync`,
// slots are mutex cells).  Determinism contract: per-row handlers write
// slot `r` only; all float reductions live in the barrier handlers and
// iterate rows in the serial loop's (reversed) order.

/// Handoff cells for one hybrid/2PS step.
struct HybridCells {
    za: Vec<Slot<Tensor>>,
    /// checkpoint, read by FP-B and BP-B rows concurrently
    zck: Slot<Arc<Tensor>>,
    zb: Vec<Slot<Tensor>>,
    tps_z: Vec<Slot<Tensor>>,
    tps_cache: Vec<Slot<Vec<Tensor>>>,
    zl: Slot<Tensor>,
    loss: Slot<f32>,
    dzl: Slot<Arc<Tensor>>,
    head_grads: Slot<(Tensor, Tensor)>,
    bp_b: Vec<Slot<(Vec<Tensor>, Tensor)>>,
    grads_mid: Slot<Vec<Tensor>>,
    dzck: Slot<Arc<Tensor>>,
    bp_a: Vec<Slot<Vec<Tensor>>>,
    out: Slot<(f32, Vec<Tensor>)>,
}

impl HybridCells {
    fn new(hp: &HybridPlan) -> Self {
        let (n_b, n_tps) = match &hp.tps {
            Some(tp) => (0, tp.rows.len()),
            None => (hp.segs[1].rows.len(), 0),
        };
        HybridCells {
            za: Slot::many(hp.segs[0].rows.len()),
            zck: Slot::new(),
            zb: Slot::many(n_b),
            tps_z: Slot::many(n_tps),
            tps_cache: Slot::many(n_tps),
            zl: Slot::new(),
            loss: Slot::new(),
            dzl: Slot::new(),
            head_grads: Slot::new(),
            bp_b: Slot::many(hp.segs[1].rows.len()),
            grads_mid: Slot::new(),
            dzck: Slot::new(),
            bp_a: Slot::many(hp.segs[0].rows.len()),
            out: Slot::new(),
        }
    }
}

fn run_hybrid_task(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    hp: &HybridPlan,
    x: &Tensor,
    y1h: &Tensor,
    cells: &HybridCells,
    task: Task,
) -> Result<()> {
    match task {
        Task::FpRow { seg: 0, row } => {
            pipe_seg_fp_row(ex, params, &hp.segs[0], row, x, &cells.za[row])
        }
        Task::FpRow { seg: _, row } => {
            let zck = cells.zck.cloned("zck")?;
            pipe_seg_fp_row(ex, params, &hp.segs[1], row, &zck, &cells.zb[row])
        }
        Task::TpsRow { row } => pipe_tps_row(ex, params, hp, row, x, cells),
        Task::CkBarrier => {
            let zck = pipe_concat(&cells.za, "fp.za")?;
            cells.zck.put("zck", Arc::new(zck))
        }
        Task::ZlBarrier => {
            let zl = match &hp.tps {
                Some(_) => pipe_concat(&cells.tps_z, "tps.z")?,
                None => pipe_concat(&cells.zb, "fp.zb")?,
            };
            cells.zl.put("zl", zl)
        }
        Task::Head => pipe_head(
            ex,
            params,
            hp.head,
            hp.n_conv,
            y1h,
            &cells.zl,
            &cells.loss,
            &cells.dzl,
            &cells.head_grads,
        ),
        Task::BpRowB { row } => pipe_bp_row_b(ex, params, &hp.segs[1], row, cells),
        Task::ReduceB => pipe_reduce_b(params, hp, cells),
        Task::BpRowA { row } => pipe_bp_row_a(ex, params, &hp.segs[0], row, x, cells),
        Task::ReduceA => pipe_reduce_a(&hp.segs[0], cells),
        t => Err(Error::Sched(format!("task {t:?} in hybrid step"))),
    }
}

fn pipe_base(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    bp: &BasePlan,
    x: &Tensor,
    y1h: &Tensor,
    out: &Slot<(f32, Vec<Tensor>)>,
) -> Result<()> {
    let mut args: Vec<TensorView> = Vec::with_capacity(2 + params.tensors.len());
    args.push(x.view());
    args.push(y1h.view());
    args.extend(params.tensors.iter().map(|t| t.view()));
    let mut res = ex.exec(bp.step, &args)?;
    let grads = res.split_off(1);
    let loss = res[0].data[0];
    out.put("base.out", (loss, grads))
}

/// FP of one segment row (segment A from x, segment B from the checkpoint).
fn pipe_seg_fp_row(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    seg: &SegPlan,
    row: usize,
    input: &Tensor,
    out: &Slot<Tensor>,
) -> Result<()> {
    let rp = &seg.rows[row];
    let seg_params = &params.tensors[seg.param_lo..seg.param_hi];
    let slab = input.slice_h(rp.in_iv[0], rp.in_iv[1])?;
    let mut args: Vec<TensorView> = Vec::with_capacity(1 + seg_params.len());
    args.push(slab);
    args.extend(seg_params.iter().map(|t| t.view()));
    let z = ex.exec(rp.fwd, &args)?.remove(0);
    out.put("fp.z", z)
}

/// One 2PS row: consume row r−1's boundary caches, produce z + own caches.
fn pipe_tps_row(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    hp: &HybridPlan,
    row: usize,
    x: &Tensor,
    cells: &HybridCells,
) -> Result<()> {
    let tp = hp
        .tps
        .as_ref()
        .ok_or_else(|| Error::Sched("tps task in non-2PS plan".into()))?;
    let rp = &tp.rows[row];
    let conv = &params.tensors[..hp.n_conv];
    let own = x.slice_h(rp.own_iv[0], rp.own_iv[1])?;
    let caches: Vec<Tensor> = if row > 0 {
        cells.tps_cache[row - 1].take("tps.cache")?
    } else {
        Vec::new()
    };
    let mut out = {
        let mut args: Vec<TensorView> = Vec::with_capacity(1 + caches.len() + conv.len());
        args.push(own);
        args.extend(caches.iter().map(|t| t.view()));
        args.extend(conv.iter().map(|t| t.view()));
        ex.exec(rp.fwd, &args)?
    };
    if out.is_empty() {
        return Err(Error::Artifact("tps row returned no outputs".into()));
    }
    let z = out.remove(0);
    cells.tps_z[row].put("tps.z", z)?;
    cells.tps_cache[row].put("tps.cache", out)
}

/// Concat barrier: take every row output in row order (deterministic).
fn pipe_concat(rows: &[Slot<Tensor>], label: &str) -> Result<Tensor> {
    let owned: Vec<Tensor> = rows.iter().map(|s| s.take(label)).collect::<Result<_>>()?;
    let views: Vec<TensorView> = owned.iter().map(|t| t.view()).collect();
    Tensor::concat_h(&views)
}

/// FP→BP boundary: the FC head, shared by hybrid and naive plans.
#[allow(clippy::too_many_arguments)]
fn pipe_head(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    head: ExecHandle,
    n_conv: usize,
    y1h: &Tensor,
    zl: &Slot<Tensor>,
    loss: &Slot<f32>,
    dzl: &Slot<Arc<Tensor>>,
    head_grads: &Slot<(Tensor, Tensor)>,
) -> Result<()> {
    let z_l = zl.take("zl")?;
    let mut out = ex.exec(
        head,
        &[
            z_l.view(),
            y1h.view(),
            params.tensors[n_conv].view(),
            params.tensors[n_conv + 1].view(),
        ],
    )?;
    if out.len() != 4 {
        return Err(Error::Artifact(format!(
            "head returned {} outputs, want [loss, dzL, dWfc, dbfc]",
            out.len()
        )));
    }
    let dbfc = out.pop().expect("len checked");
    let dwfc = out.pop().expect("len checked");
    let dz_l = out.pop().expect("len checked");
    let loss_v = out.pop().expect("len checked").data[0];
    loss.put("loss", loss_v)?;
    dzl.put("dzl", Arc::new(dz_l))?;
    head_grads.put("head_grads", (dwfc, dbfc))
}

/// BP of one segment-B row: slab from the checkpoint, δ from the head.
fn pipe_bp_row_b(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    seg_b: &SegPlan,
    row: usize,
    cells: &HybridCells,
) -> Result<()> {
    let rp = &seg_b.rows[row];
    let zck = cells.zck.cloned("zck")?;
    let dzl = cells.dzl.cloned("dzl")?;
    let seg_params = &params.tensors[seg_b.param_lo..seg_b.param_hi];
    let slab = zck.slice_h(rp.in_iv[0], rp.in_iv[1])?;
    let dz = dzl.slice_h(rp.out_iv[0], rp.out_iv[1])?;
    let mut out = {
        let mut args: Vec<TensorView> = Vec::with_capacity(2 + seg_params.len());
        args.push(slab);
        args.extend(seg_params.iter().map(|t| t.view()));
        args.push(dz);
        ex.exec(rp.bwd, &args)?
    };
    let _z = out
        .pop()
        .ok_or_else(|| Error::Artifact("segB bwd returned no outputs".into()))?;
    let dx = out
        .pop()
        .ok_or_else(|| Error::Artifact("segB bwd missing dx output".into()))?;
    cells.bp_b[row].put("bp_b", (out, dx))
}

/// Reduce barrier after BP-B: fold row gradients and δ-accumulate dz_ck in
/// the serial loop's reversed row order — this is what keeps the pipelined
/// loss/params bit-identical.
fn pipe_reduce_b(params: &ParamSet, hp: &HybridPlan, cells: &HybridCells) -> Result<()> {
    let seg_b = &hp.segs[1];
    let mut grads = params.grad_zeros();
    let (dwfc, dbfc) = cells.head_grads.take("head_grads")?;
    grads[hp.n_conv] = dwfc;
    grads[hp.n_conv + 1] = dbfc;
    let zck = cells.zck.cloned("zck")?;
    let mut dz_ck = Tensor::zeros(&zck.shape);
    for (r, rp) in seg_b.rows.iter().enumerate().rev() {
        let (row_grads, dx) = cells.bp_b[r].take("bp_b")?;
        for (i, g) in row_grads.into_iter().enumerate() {
            grads[seg_b.param_lo + i].axpy(1.0, &g)?;
        }
        dz_ck.add_h(rp.in_iv[0], &dx)?;
    }
    cells.grads_mid.put("grads_mid", grads)?;
    cells.dzck.put("dzck", Arc::new(dz_ck))
}

/// BP of one segment-A row: slab from x, δ from the dz_ck accumulator.
fn pipe_bp_row_a(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    seg_a: &SegPlan,
    row: usize,
    x: &Tensor,
    cells: &HybridCells,
) -> Result<()> {
    let rp = &seg_a.rows[row];
    let dzck = cells.dzck.cloned("dzck")?;
    let seg_params = &params.tensors[seg_a.param_lo..seg_a.param_hi];
    let slab = x.slice_h(rp.in_iv[0], rp.in_iv[1])?;
    let dz = dzck.slice_h(rp.out_iv[0], rp.out_iv[1])?;
    let mut out = {
        let mut args: Vec<TensorView> = Vec::with_capacity(2 + seg_params.len());
        args.push(slab);
        args.extend(seg_params.iter().map(|t| t.view()));
        args.push(dz);
        ex.exec(rp.bwd, &args)?
    };
    out.pop()
        .ok_or_else(|| Error::Artifact("segA bwd returned no outputs".into()))?;
    cells.bp_a[row].put("bp_a", out)
}

/// Final reduce: fold segment A's row gradients (reversed order) and emit
/// the step result.
fn pipe_reduce_a(seg_a: &SegPlan, cells: &HybridCells) -> Result<()> {
    let mut grads = cells.grads_mid.take("grads_mid")?;
    for r in (0..seg_a.rows.len()).rev() {
        let row_grads = cells.bp_a[r].take("bp_a")?;
        for (i, g) in row_grads.into_iter().enumerate() {
            grads[seg_a.param_lo + i].axpy(1.0, &g)?;
        }
    }
    let loss = cells.loss.take("loss")?;
    cells.out.put("out", (loss, grads))
}

/// Handoff cells for one naive step.
struct NaiveCells {
    z: Vec<Slot<Tensor>>,
    zl: Slot<Tensor>,
    loss: Slot<f32>,
    dzl: Slot<Arc<Tensor>>,
    head_grads: Slot<(Tensor, Tensor)>,
    bp: Vec<Slot<Vec<Tensor>>>,
    out: Slot<(f32, Vec<Tensor>)>,
}

impl NaiveCells {
    fn new(np: &NaivePlan) -> Self {
        NaiveCells {
            z: Slot::many(np.rows.len()),
            zl: Slot::new(),
            loss: Slot::new(),
            dzl: Slot::new(),
            head_grads: Slot::new(),
            bp: Slot::many(np.rows.len()),
            out: Slot::new(),
        }
    }
}

fn run_naive_task(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    np: &NaivePlan,
    x: &Tensor,
    y1h: &Tensor,
    cells: &NaiveCells,
    task: Task,
) -> Result<()> {
    let conv = &params.tensors[..np.n_conv];
    match task {
        Task::NaiveFp { row } => {
            let rp = &np.rows[row];
            let slab = x.slice_h(rp.x_iv[0], rp.x_iv[1])?;
            let mut args: Vec<TensorView> = Vec::with_capacity(1 + conv.len());
            args.push(slab);
            args.extend(conv.iter().map(|t| t.view()));
            let z = ex.exec(rp.fwd, &args)?.remove(0);
            cells.z[row].put("naive.z", z)
        }
        Task::NaiveZl => {
            let zl = pipe_concat(&cells.z, "naive.z")?;
            cells.zl.put("naive.zl", zl)
        }
        Task::NaiveHead => pipe_head(
            ex,
            params,
            np.head,
            np.n_conv,
            y1h,
            &cells.zl,
            &cells.loss,
            &cells.dzl,
            &cells.head_grads,
        ),
        Task::NaiveBp { row } => {
            let rp = &np.rows[row];
            let dzl = cells.dzl.cloned("dzl")?;
            let slab = x.slice_h(rp.x_iv[0], rp.x_iv[1])?;
            let dz = dzl.slice_h(rp.z_iv[0], rp.z_iv[1])?;
            let mut out = {
                let mut args: Vec<TensorView> = Vec::with_capacity(2 + conv.len());
                args.push(slab);
                args.extend(conv.iter().map(|t| t.view()));
                args.push(dz);
                ex.exec(rp.bwd, &args)?
            };
            out.pop()
                .ok_or_else(|| Error::Artifact("naive bwd returned no outputs".into()))?;
            cells.bp[row].put("naive.bp", out)
        }
        Task::NaiveReduce => {
            let mut grads = params.grad_zeros();
            let (dwfc, dbfc) = cells.head_grads.take("head_grads")?;
            grads[np.n_conv] = dwfc;
            grads[np.n_conv + 1] = dbfc;
            for r in (0..np.rows.len()).rev() {
                let row_grads = cells.bp[r].take("naive.bp")?;
                for (i, g) in row_grads.into_iter().enumerate() {
                    grads[i].axpy(1.0, &g)?;
                }
            }
            let loss = cells.loss.take("loss")?;
            cells.out.put("out", (loss, grads))
        }
        t => Err(Error::Sched(format!("task {t:?} in naive step"))),
    }
}

/// Convenience: train `steps` steps on the synthetic corpus; returns the
/// per-step losses.
pub fn train_loop(
    trainer: &mut Trainer<'_>,
    corpus: &SyntheticCorpus,
    steps: u64,
    log_every: u64,
) -> Result<Vec<f32>> {
    let b = trainer.rt.manifest.model.batch;
    let mut losses = Vec::with_capacity(steps as usize);
    for s in 0..steps {
        let (x, y, _) = corpus.batch(s, b);
        let stats = trainer.step(&x, &y)?;
        if log_every > 0 && s % log_every == 0 {
            println!(
                "  [{}] step {s:4}  loss {:.4}  peak {:>9}  {:.1} ms  {} execs",
                trainer.mode().label(),
                stats.loss,
                crate::metrics::fmt_bytes(stats.peak_bytes),
                stats.step_ms,
                stats.executions
            );
        }
        if !stats.loss.is_finite() {
            return Err(Error::Runtime(format!(
                "loss diverged to {} at step {s}",
                stats.loss
            )));
        }
        losses.push(stats.loss);
    }
    Ok(losses)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::DeviceModel;
    use crate::shard::{DevicePreset, DeviceSpec, LinkKind, ShardConfig, Topology};

    #[test]
    fn naive_row_extents_equal_split() {
        let ivs = naive_row_extents(32, 4).unwrap();
        assert_eq!(ivs.len(), 4);
        assert_eq!(ivs[0], [0, 8]);
        assert_eq!(ivs[3], [24, 32]);
        // cover the full range with no gaps
        for w in ivs.windows(2) {
            assert_eq!(w[0][1], w[1][0]);
        }
    }

    #[test]
    fn naive_row_extents_rejects_remainder() {
        // the seed silently truncated h=33 n=4 to 4×8 rows, dropping row 32
        let err = naive_row_extents(33, 4).unwrap_err();
        match err {
            Error::InfeasiblePlan(msg) => {
                assert!(msg.contains("remainder"), "{msg}");
            }
            other => panic!("expected InfeasiblePlan, got {other:?}"),
        }
        assert!(naive_row_extents(8, 0).is_err());
        assert!(naive_row_extents(0, 2).is_err());
    }

    /// A miniature manifest with every executable the four modes resolve,
    /// carrying **shape-accurate** I/O signatures (batch 1, c 1, H 8, W 4;
    /// two rows per phase) so [`FakeExec`] can validate argument shapes
    /// and the DAG lowering derives real byte estimates:
    ///
    /// * x [1,1,8,4]; seg rows: in [0,5]/[3,8] (halo slabs), out [0,4]/[4,8]
    /// * params: W1 [1,1,3,3], b1 [1], Wfc [32,2], bfc [2]
    /// * head: (zL, y1h, Wfc, bfc) → (loss, dzL, dWfc, dbfc)
    fn plan_manifest(h: usize, naive_rows: usize) -> Manifest {
        let exes: &[(&str, &str, &str)] = &[
            (
                "base_step",
                "[[1,1,8,4],[1,2],[1,1,3,3],[1],[32,2],[2]]",
                "[[1],[1,1,3,3],[1],[32,2],[2]]",
            ),
            ("base_fwd", "[[1,1,8,4],[1,1,3,3],[1]]", "[[1,1,8,4]]"),
            (
                "head",
                "[[1,1,8,4],[1,2],[32,2],[2]]",
                "[[1],[1,1,8,4],[32,2],[2]]",
            ),
            ("segA_row0_fwd", "[[1,1,5,4],[1,1,3,3],[1]]", "[[1,1,4,4]]"),
            (
                "segA_row0_bwd",
                "[[1,1,5,4],[1,1,3,3],[1],[1,1,4,4]]",
                "[[1,1,3,3],[1],[1,1,4,4]]",
            ),
            ("segA_row1_fwd", "[[1,1,5,4],[1,1,3,3],[1]]", "[[1,1,4,4]]"),
            (
                "segA_row1_bwd",
                "[[1,1,5,4],[1,1,3,3],[1],[1,1,4,4]]",
                "[[1,1,3,3],[1],[1,1,4,4]]",
            ),
            ("segB_row0_fwd", "[[1,1,5,4],[1,1,3,3],[1]]", "[[1,1,4,4]]"),
            (
                "segB_row0_bwd",
                "[[1,1,5,4],[1,1,3,3],[1],[1,1,4,4]]",
                "[[1,1,3,3],[1],[1,1,5,4],[1,1,4,4]]",
            ),
            ("segB_row1_fwd", "[[1,1,5,4],[1,1,3,3],[1]]", "[[1,1,4,4]]"),
            (
                "segB_row1_bwd",
                "[[1,1,5,4],[1,1,3,3],[1],[1,1,4,4]]",
                "[[1,1,3,3],[1],[1,1,5,4],[1,1,4,4]]",
            ),
            (
                "tps_row0_fwd",
                "[[1,1,4,4],[1,1,3,3],[1]]",
                "[[1,1,4,4],[1,1,1,4],[1,1,1,4]]", // z + 2 caches
            ),
            (
                "tps_row1_fwd",
                "[[1,1,4,4],[1,1,1,4],[1,1,1,4],[1,1,3,3],[1]]",
                "[[1,1,4,4]]", // z only (last row)
            ),
            ("naive_row0_fwd", "[[1,1,4,4],[1,1,3,3],[1]]", "[[1,1,4,4]]"),
            (
                "naive_row0_bwd",
                "[[1,1,4,4],[1,1,3,3],[1],[1,1,4,4]]",
                "[[1,1,3,3],[1],[1,1,4,4]]",
            ),
            ("naive_row1_fwd", "[[1,1,4,4],[1,1,3,3],[1]]", "[[1,1,4,4]]"),
            (
                "naive_row1_bwd",
                "[[1,1,4,4],[1,1,3,3],[1],[1,1,4,4]]",
                "[[1,1,3,3],[1],[1,1,4,4]]",
            ),
        ];
        let exe_json: Vec<String> = exes
            .iter()
            .map(|(name, inputs, outputs)| {
                format!(
                    r#"{{"name": "{name}", "path": "{name}.hlo", "kind": "k",
                         "inputs": {inputs}, "outputs": {outputs}}}"#
                )
            })
            .collect();
        let seg = |name: &str| {
            format!(
                r#"{{"name": "{name}", "h_in": {h}, "h_out": {h}, "c_in": 1, "c_out": 1,
                     "param_lo": 0, "param_hi": 2,
                     "rows": [
                       {{"out_iv": [0, 4], "in_iv": [0, 5], "chain": []}},
                       {{"out_iv": [4, 8], "in_iv": [3, 8], "chain": []}}
                     ]}}"#
            )
        };
        let text = format!(
            r#"{{
              "model": {{
                "name": "t", "batch": 1, "h": {h}, "w": 4, "n_classes": 2,
                "layers": [], "heights": [{h}, {h}], "w_out": 4, "fc_in": 32,
                "param_shapes": [[1, 1, 3, 3], [1], [32, 2], [2]],
                "n_conv_params": 2
              }},
              "plan": {{
                "ckpt_split": 1, "n_rows": 2, "tps_rows": 2, "naive_rows": {naive_rows},
                "segments": [{segA}, {segB}],
                "tps": {{
                  "cuts": [0, 4, 8],
                  "rows": [
                    {{"own_iv": [0, 4], "bounds": [[0, 4]], "cache_in": [null], "cache_out": [[3, 4]]}},
                    {{"own_iv": [4, 8], "bounds": [[4, 8]], "cache_in": [[3, 4]], "cache_out": [null]}}
                  ]
                }}
              }},
              "executables": [{exes}]
            }}"#,
            segA = seg("segA"),
            segB = seg("segB"),
            exes = exe_json.join(",\n")
        );
        Manifest::parse(&text).expect("test manifest parses")
    }

    #[test]
    fn step_plan_interns_everything_up_front() {
        let man = plan_manifest(8, 2);
        for mode in [Mode::Base, Mode::RowHybrid, Mode::Tps, Mode::Naive] {
            let mut tracker = Tracker::new();
            let plan = StepPlan::build(&man, mode, &mut tracker).unwrap();
            match (&plan.kind, mode) {
                (PlanKind::Base(bp), Mode::Base) => {
                    assert_eq!(bp.step.index(), man.index_of("base_step").unwrap());
                    assert_eq!(bp.fwd.index(), man.index_of("base_fwd").unwrap());
                    assert_eq!(bp.n_conv, 2);
                }
                (PlanKind::Hybrid(hp), Mode::RowHybrid) => {
                    assert!(hp.tps.is_none());
                    assert_eq!(hp.segs.len(), 2);
                    assert_eq!(hp.segs[0].rows.len(), 2);
                    let rp = &hp.segs[1].rows[1];
                    assert_eq!(rp.fwd.index(), man.index_of("segB_row1_fwd").unwrap());
                    assert_eq!(rp.bwd.index(), man.index_of("segB_row1_bwd").unwrap());
                    assert_eq!(rp.in_iv, [3, 8]);
                    assert_eq!(rp.out_iv, [4, 8]);
                    // ids resolve to the exact strings the seed allocated,
                    // so tracker accounting stays byte-identical
                    assert_eq!(tracker.name(rp.slab_id), "fp.segB.slab1");
                    assert_eq!(tracker.name(rp.bp_slab_id), "bp.segB.slab1");
                    assert_eq!(tracker.name(hp.segs[1].out_id), "fp.segB.out");
                    assert_eq!(tracker.name(hp.dzl_id), "dzL");
                }
                (PlanKind::Hybrid(hp), Mode::Tps) => {
                    let tp = hp.tps.as_ref().expect("2PS plan");
                    assert_eq!(tp.rows.len(), 2);
                    // cache count derived from the executable signature
                    assert_eq!(tp.rows[0].cache_ids.len(), 2);
                    assert_eq!(tp.rows[1].cache_ids.len(), 0);
                    assert_eq!(tracker.name(tp.rows[0].cache_ids[1]), "tps.cache0.1");
                    assert_eq!(tracker.name(tp.zl_id), "tps.zL");
                }
                (PlanKind::Naive(np), Mode::Naive) => {
                    assert_eq!(np.rows.len(), 2);
                    assert_eq!(np.rows[0].x_iv, [0, 4]);
                    assert_eq!(np.rows[1].x_iv, [4, 8]);
                    assert_eq!(np.rows[1].z_iv, [4, 8]);
                }
                (kind, mode) => panic!("unexpected plan {kind:?} for {mode:?}"),
            }
        }
    }

    #[test]
    fn step_plan_flags_uneven_naive_split() {
        // h=8, naive_rows=3: 8 % 3 != 0 — the seed truncated, we flag
        let man = plan_manifest(8, 3);
        let mut tracker = Tracker::new();
        let plan = StepPlan::build(&man, Mode::Naive, &mut tracker).unwrap();
        match &plan.kind {
            PlanKind::NaiveInfeasible(msg) => assert!(msg.contains("remainder"), "{msg}"),
            other => panic!("expected NaiveInfeasible, got {other:?}"),
        }
        // lowering an infeasible plan is a typed error, not a panic
        match plan.lower(&man) {
            Err(Error::InfeasiblePlan(msg)) => assert!(msg.contains("remainder"), "{msg}"),
            other => panic!("expected InfeasiblePlan, got {:?}", other.is_ok()),
        }
        // the other modes are unaffected by the naive split
        assert!(StepPlan::build(&man, Mode::RowHybrid, &mut tracker).is_ok());
    }

    #[test]
    fn step_plan_errors_on_missing_executable() {
        let mut man = plan_manifest(8, 2);
        man.executables.retain(|e| e.name != "segB_row1_bwd");
        let mut tracker = Tracker::new();
        match StepPlan::build(&man, Mode::RowHybrid, &mut tracker) {
            Err(Error::Artifact(msg)) => assert!(msg.contains("segB_row1_bwd"), "{msg}"),
            other => panic!("expected Artifact error, got {:?}", other.is_ok()),
        }
    }

    // ---------------- scheduler: lowering + pipelined execution ----------------

    /// Deterministic stand-in backend: outputs are a pure function of the
    /// executable identity and every input element (shape-checked against
    /// the manifest signature), so any arg-reorder / wrong-cache /
    /// wrong-slice bug in the pipelined path changes the bits.
    struct FakeExec {
        man: Manifest,
    }

    impl ExecBackend for FakeExec {
        fn exec(&self, h: ExecHandle, args: &[TensorView<'_>]) -> Result<Vec<Tensor>> {
            let info = self
                .man
                .executables
                .get(h.index())
                .ok_or_else(|| Error::Artifact(format!("fake: bad handle {}", h.index())))?;
            if args.len() != info.inputs.len() {
                return Err(Error::Artifact(format!(
                    "fake {}: {} args, signature wants {}",
                    info.name,
                    args.len(),
                    info.inputs.len()
                )));
            }
            for (i, (v, expect)) in args.iter().zip(&info.inputs).enumerate() {
                if v.dims() != expect.as_slice() {
                    return Err(Error::Artifact(format!(
                        "fake {}: input {i} shape {:?} != {:?}",
                        info.name,
                        v.dims(),
                        expect
                    )));
                }
            }
            // position-weighted checksum over all inputs, in arg order
            let mut acc = 0.0f32;
            for (i, v) in args.iter().enumerate() {
                let mut s = 0.0f32;
                let mut e = 0usize;
                for chunk in v.chunks() {
                    for val in chunk {
                        s += val * ((e % 7 + 1) as f32);
                        e += 1;
                    }
                }
                acc += s * ((i + 1) as f32) * 0.01;
            }
            info.outputs
                .iter()
                .enumerate()
                .map(|(k, shape)| {
                    let n: usize = shape.iter().product();
                    let base = (h.index() * 31 + k * 7) as f32 * 0.001;
                    let data = (0..n)
                        .map(|j| ((j % 13) as f32) * 0.01 + (base + acc * 0.25).sin() * 0.1)
                        .collect();
                    Tensor::new(shape.clone(), data)
                })
                .collect()
        }
    }

    fn test_batch() -> (Tensor, Tensor) {
        let x = Tensor::new(
            vec![1, 1, 8, 4],
            (0..32).map(|i| (i as f32 * 0.37).sin()).collect(),
        )
        .unwrap();
        let y = Tensor::new(vec![1, 2], vec![1.0, 0.0]).unwrap();
        (x, y)
    }

    /// Run `steps` serial steps with the fake backend; returns per-step
    /// losses, final params and the per-step tracker peaks.
    fn run_serial(man: &Manifest, mode: Mode, steps: usize) -> (Vec<f32>, ParamSet, Vec<u64>) {
        let mut tracker = Tracker::new();
        let plan = StepPlan::build(man, mode, &mut tracker).unwrap();
        let ex = FakeExec { man: man.clone() };
        let mut params = ParamSet::init(&man.model, 42);
        let mut opt = Optimizer::sgd(0.05);
        let (x, y) = test_batch();
        let mut losses = Vec::new();
        let mut peaks = Vec::new();
        for _ in 0..steps {
            tracker.reset();
            let (loss, grads) = match &plan.kind {
                PlanKind::Base(bp) => {
                    Trainer::step_base(&ex, &params, &mut tracker, bp, &x, &y).unwrap()
                }
                PlanKind::Hybrid(hp) => {
                    Trainer::step_hybrid(&ex, &params, &mut tracker, hp, &x, &y).unwrap()
                }
                PlanKind::Naive(np) => {
                    Trainer::step_naive(&ex, &params, &mut tracker, np, &x, &y).unwrap()
                }
                PlanKind::NaiveInfeasible(m) => panic!("infeasible: {m}"),
            };
            opt.step(&mut params, &grads).unwrap();
            losses.push(loss);
            peaks.push(tracker.peak());
        }
        (losses, params, peaks)
    }

    /// Run `steps` pipelined steps; returns losses, final params, per-step
    /// admission peaks and the last trace.
    fn run_pipelined(
        man: &Manifest,
        mode: Mode,
        steps: usize,
        workers: usize,
        budget: u64,
    ) -> (Vec<f32>, ParamSet, Vec<u64>, Trace) {
        let mut tracker = Tracker::new();
        let plan = StepPlan::build(man, mode, &mut tracker).unwrap();
        let pipe = plan.lower(man).unwrap();
        let ex = FakeExec { man: man.clone() };
        let cfg = SchedConfig::pipelined(workers).with_budget(budget);
        let mut params = ParamSet::init(&man.model, 42);
        let mut opt = Optimizer::sgd(0.05);
        let (x, y) = test_batch();
        let mut losses = Vec::new();
        let mut peaks = Vec::new();
        let mut last = Trace::default();
        for _ in 0..steps {
            let (loss, grads, outcome) =
                Trainer::step_pipelined(&ex, &plan, &pipe, &params, &cfg, None, &x, &y)
                    .unwrap();
            outcome.trace.check_complete(&pipe.dag).unwrap();
            opt.step(&mut params, &grads).unwrap();
            losses.push(loss);
            peaks.push(outcome.peak_bytes);
            last = outcome.trace;
        }
        (losses, params, peaks, last)
    }

    /// Run `steps` sharded-pipelined steps over an arbitrary (possibly
    /// heterogeneous) topology; ledgers are set to the per-device
    /// serial-order replay peaks clamped to each device's memory and
    /// asserted from every step's trace.  Returns losses, final params
    /// and the last trace + plan for shape checks.
    fn run_sharded(
        man: &Manifest,
        mode: Mode,
        steps: usize,
        workers: usize,
        topo: &Topology,
        policy: shard::PartitionPolicy,
    ) -> (Vec<f32>, ParamSet, Trace, ShardPlan) {
        let devices = topo.len();
        let mut tracker = Tracker::new();
        let plan = StepPlan::build(man, mode, &mut tracker).unwrap();
        let pipe = plan.lower(man).unwrap();
        let mut splan =
            ShardPlan::build(pipe.dag(), topo, policy, topo.budgets(0)).unwrap();
        // tight per-device ledgers: the serial-order replay peak, clamped
        // to the device's own memory (the trainer-path budget shape)
        let ledgers = splan.replay_ledgers(topo, 0).unwrap();
        splan.set_budgets(ledgers.clone()).unwrap();
        assert!(splan.check_budgets().is_ok());
        // the pool is constructed once and reused by every step below
        let state = ShardState {
            plan: splan,
            exec: ShardedExecutor::new(workers),
        };
        let ex = FakeExec { man: man.clone() };
        let cfg = SchedConfig::pipelined(workers);
        let mut params = ParamSet::init(&man.model, 42);
        let mut opt = Optimizer::sgd(0.05);
        let (x, y) = test_batch();
        let mut losses = Vec::new();
        let mut last = Trace::default();
        for _ in 0..steps {
            let (loss, grads, outcome) = Trainer::step_pipelined(
                &ex,
                &plan,
                &pipe,
                &params,
                &cfg,
                Some(&state),
                &x,
                &y,
            )
            .unwrap();
            outcome.trace.check_complete(state.plan.dag()).unwrap();
            // every per-device admission ledger respected, from the trace
            for d in 0..devices {
                assert!(
                    outcome.device_peaks[d] <= ledgers[d],
                    "{mode:?} {policy:?} d{d}: peak {} > ledger {}",
                    outcome.device_peaks[d],
                    ledgers[d]
                );
                assert!(outcome.trace.max_in_flight_on(d) <= ledgers[d]);
            }
            opt.step(&mut params, &grads).unwrap();
            losses.push(loss);
            last = outcome.trace;
        }
        (losses, params, last, state.plan)
    }

    fn assert_bits_equal(a: &ParamSet, b: &ParamSet, ctx: &str) {
        assert_eq!(a.tensors.len(), b.tensors.len(), "{ctx}: param count");
        for (i, (ta, tb)) in a.tensors.iter().zip(&b.tensors).enumerate() {
            assert_eq!(ta.shape, tb.shape, "{ctx}: param {i} shape");
            for (j, (va, vb)) in ta.data.iter().zip(&tb.data).enumerate() {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "{ctx}: param {i}[{j}] {va} vs {vb}"
                );
            }
        }
    }

    /// The acceptance bar: pipelined == serial, bit for bit, over ≥3 steps
    /// (params feed back into step n+1, so drift would compound) in all
    /// four modes, across worker counts and with a tight budget.
    #[test]
    fn pipelined_matches_serial_bitwise_in_all_modes() {
        let man = plan_manifest(8, 2);
        for mode in [Mode::Base, Mode::RowHybrid, Mode::Tps, Mode::Naive] {
            let (sl, sp, _) = run_serial(&man, mode, 3);
            for (workers, budget) in [(1, u64::MAX), (2, u64::MAX), (4, u64::MAX), (4, 600)] {
                let (pl, pp, _, _) = run_pipelined(&man, mode, 3, workers, budget);
                let ctx = format!("{mode:?} w={workers} b={budget}");
                assert_eq!(sl.len(), pl.len());
                for (a, b) in sl.iter().zip(&pl) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: loss {a} vs {b}");
                }
                assert_bits_equal(&sp, &pp, &ctx);
            }
        }
    }

    /// Admission control: with the budget set to the serial-order replay
    /// peak (working sets + parked handoff bytes — the exact residency a
    /// serial execution of the DAG holds, from the shard replay on one
    /// device), the pipelined peak never exceeds it.  The ledger now
    /// covers interim slot bytes too, so the tracker peak (which frees z
    /// rows at the concat) is no longer the right bound — the replay peak
    /// is.
    #[test]
    fn admission_peak_stays_under_serial_replay_peak() {
        let man = plan_manifest(8, 2);
        for mode in [Mode::RowHybrid, Mode::Tps] {
            let (sl, _, _) = run_serial(&man, mode, 1);
            let mut tracker = Tracker::new();
            let plan = StepPlan::build(&man, mode, &mut tracker).unwrap();
            let pipe = plan.lower(&man).unwrap();
            let topo = Topology::uniform(1, DeviceModel::rtx3090(), shard::LinkKind::Pcie);
            let splan = ShardPlan::build(
                pipe.dag(),
                &topo,
                shard::PartitionPolicy::Blocked,
                vec![u64::MAX],
            )
            .unwrap();
            let replay_peak = splan.replay_peaks().unwrap()[0];
            assert!(
                pipe.dag().max_est_bytes() <= replay_peak,
                "{mode:?}: replay peak must dominate every single node"
            );
            let (pl, _, ppeaks, _) = run_pipelined(&man, mode, 1, 4, replay_peak);
            assert!(
                ppeaks[0] <= replay_peak,
                "{mode:?}: pipelined peak {} > serial replay peak {replay_peak}",
                ppeaks[0]
            );
            // and the budget cap costs no accuracy
            assert_eq!(sl[0].to_bits(), pl[0].to_bits(), "{mode:?}");
        }
    }

    /// The topologies the bit-identity matrix re-proves determinism
    /// over: uniform 1/2/4 RTX 3090s plus two genuinely heterogeneous
    /// mixes (rtx3090+a100 over PCIe, 2×rtx3090+2×a100 over NVLink).
    fn proof_topologies() -> Vec<(&'static str, Topology)> {
        let d90 = DeviceModel::rtx3090();
        let a100 = DeviceModel::a100_80g();
        vec![
            ("rtx3090x1", Topology::uniform(1, d90.clone(), LinkKind::NvLink)),
            ("rtx3090x2", Topology::uniform(2, d90.clone(), LinkKind::NvLink)),
            ("rtx3090x4", Topology::uniform(4, d90.clone(), LinkKind::NvLink)),
            (
                "rtx3090+a100",
                Topology::new(vec![d90.clone(), a100.clone()], LinkKind::Pcie),
            ),
            (
                "rtx3090x2+a100x2",
                Topology::new(vec![d90.clone(), d90, a100.clone(), a100], LinkKind::NvLink),
            ),
        ]
    }

    const ALL_POLICIES: [shard::PartitionPolicy; 3] = [
        shard::PartitionPolicy::Blocked,
        shard::PartitionPolicy::CostBalanced,
        shard::PartitionPolicy::DpBoundary,
    ];

    /// The shard acceptance bar: sharded execution is bit-identical to
    /// serial over ≥3 steps (params feed forward, drift would compound)
    /// across all 4 modes × uniform {1, 2, 4}-device *and* heterogeneous
    /// rtx3090+a100 topologies × all three partition policies, with
    /// every per-device admission ledger (clamped to that device's
    /// memory) respected — asserted inside `run_sharded` from the trace
    /// — and transfers appearing exactly when the partition splits an
    /// edge.
    #[test]
    fn sharded_matches_serial_bitwise_across_topologies_and_policies() {
        let man = plan_manifest(8, 2);
        for mode in [Mode::Base, Mode::RowHybrid, Mode::Tps, Mode::Naive] {
            let (sl, sp, _) = run_serial(&man, mode, 3);
            for (name, topo) in proof_topologies() {
                for policy in ALL_POLICIES {
                    let (pl, pp, _, splan) =
                        run_sharded(&man, mode, 3, 4, &topo, policy);
                    let ctx = format!("{mode:?} topo={name} {policy:?}");
                    assert_eq!(sl.len(), pl.len());
                    for (a, b) in sl.iter().zip(&pl) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: loss {a} vs {b}");
                    }
                    assert_bits_equal(&sp, &pp, &ctx);
                    if topo.len() == 1 {
                        assert!(
                            splan.transfers().is_empty(),
                            "{ctx}: one device must not transfer"
                        );
                    }
                }
            }
        }
    }

    /// Sharded traces are reproducible: same plan, same pool ⇒ same
    /// canonical view (the ready-pick is a pure function of
    /// `(NodeId, DeviceId)` and ledger state, never thread timing) —
    /// on heterogeneous topologies too.
    #[test]
    fn sharded_trace_is_canonical_deterministic() {
        let man = plan_manifest(8, 2);
        let topo = Topology::new(
            vec![DeviceModel::rtx3090(), DeviceModel::a100_80g()],
            LinkKind::NvLink,
        );
        for policy in ALL_POLICIES {
            let (_, _, t1, _) = run_sharded(&man, Mode::RowHybrid, 1, 4, &topo, policy);
            let (_, _, t2, _) = run_sharded(&man, Mode::RowHybrid, 1, 4, &topo, policy);
            assert_eq!(t1.canonical(), t2.canonical(), "{policy:?}");
        }
    }

    /// Regression (PR 4 satellite): `set_sched(Pipelined)` used to
    /// install the new config even when the step plan was never lowered,
    /// leaving `shard == None` — the trainer reported pipelined while
    /// stepping serially.  Reconfiguration is now transactional: a typed
    /// error and the previous (working) configuration fully preserved.
    #[test]
    fn sched_reconfiguration_is_transactional() {
        let man = plan_manifest(8, 2);
        let mut tracker = Tracker::new();
        let plan = StepPlan::build(&man, Mode::RowHybrid, &mut tracker).unwrap();
        let pipe = plan.lower(&man).unwrap();

        let mut st = SchedState::new();
        let good = SchedConfig::pipelined(2);
        st.set(Some(&pipe), good.clone(), 0).unwrap();
        assert!(st.shard.is_some(), "pipelined builds the sharded state");

        // (a) pipelined with no lowered plan: Error::Sched, nothing moves
        match st.set(None, SchedConfig::pipelined(4), 0) {
            Err(Error::Sched(msg)) => assert!(msg.contains("never"), "{msg}"),
            other => panic!("expected Error::Sched, got ok={:?}", other.is_ok()),
        }
        assert_eq!(st.cfg, good, "failed set must preserve the config");
        assert!(st.shard.is_some(), "…and the working sharded state");
        assert_eq!(st.shard.as_ref().unwrap().plan().devices(), 1);

        // (b) a deliberately tiny device: its clamped budget is below the
        // serial replay peak — would OOM on real hardware, so the
        // reconfiguration is rejected and the old config survives
        let tiny = SchedConfig::pipelined(2).with_shard(ShardConfig::heterogeneous(vec![
            DeviceSpec::new(DevicePreset::Rtx3090).with_hbm(64),
        ]));
        match st.set(Some(&pipe), tiny, 0) {
            Err(Error::InfeasiblePlan(msg)) => {
                assert!(msg.contains("exceeds"), "{msg}")
            }
            other => panic!("expected InfeasiblePlan, got ok={:?}", other.is_ok()),
        }
        assert_eq!(st.cfg, good);
        assert!(st.shard.is_some());

        // (c) falling back to serial always succeeds and drops the pool
        st.set(None, SchedConfig::default(), 0).unwrap();
        assert!(st.shard.is_none());
    }

    /// Regression (PR 4 satellite): per-device admission budgets used to
    /// be `vec![cfg.mem_budget; devices]`, ignoring each device's actual
    /// memory.  They now derive from `Topology::budgets(ξ)` clamped by
    /// the configured budget — a small device's ledger can never exceed
    /// its usable HBM minus the always-resident bytes.
    #[test]
    fn per_device_budgets_clamp_to_device_memory() {
        let man = plan_manifest(8, 2);
        let mut tracker = Tracker::new();
        let plan = StepPlan::build(&man, Mode::RowHybrid, &mut tracker).unwrap();
        let pipe = plan.lower(&man).unwrap();

        // mixed topology: stock rtx3090 + a 1 MiB-scaled a100
        let small = 1u64 << 20;
        let cfg = SchedConfig::pipelined(2).with_shard(ShardConfig::heterogeneous(vec![
            DeviceSpec::new(DevicePreset::Rtx3090),
            DeviceSpec::new(DevicePreset::A100).with_hbm(small),
        ]));
        let xi = 1u64 << 10;
        let ss = ShardState::build(&pipe, &cfg, xi).unwrap();
        let budgets = ss.plan().budgets();
        assert_eq!(
            budgets[0],
            DeviceModel::rtx3090().usable_hbm() - xi,
            "an unbounded mem_budget clamps to the device"
        );
        assert_eq!(budgets[1], (small - small / 16) - xi);

        // an explicit budget below both devices wins everywhere
        let cfg = SchedConfig {
            mem_budget: 4096,
            ..cfg
        };
        let ss = ShardState::build(&pipe, &cfg, xi).unwrap();
        assert!(ss.plan().budgets().iter().all(|&b| b == 4096));
    }

    /// Deterministic trace: same DAG, same config ⇒ same canonical view,
    /// and every node dispatched/finished exactly once.
    #[test]
    fn pipelined_trace_is_canonical_deterministic() {
        let man = plan_manifest(8, 2);
        for mode in [Mode::RowHybrid, Mode::Tps, Mode::Naive] {
            let (_, _, _, t1) = run_pipelined(&man, mode, 1, 4, u64::MAX);
            let (_, _, _, t2) = run_pipelined(&man, mode, 1, 4, u64::MAX);
            assert_eq!(t1.canonical(), t2.canonical(), "{mode:?}");
        }
    }

    /// DAG shape properties (the paper's dependency structure, verbatim):
    /// OverL rows edge-free, 2PS rows exactly chain-shaped, barriers at
    /// the checkpoint / z^L / FP→BP boundaries.
    #[test]
    fn lowered_dag_shapes_match_the_papers_dependency_structure() {
        let man = plan_manifest(8, 2);
        let mut tracker = Tracker::new();

        // OverL-H
        let plan = StepPlan::build(&man, Mode::RowHybrid, &mut tracker).unwrap();
        let pipe = plan.lower(&man).unwrap();
        let dag = pipe.dag();
        assert!(dag.validate().is_ok());
        let ck = dag.find("barrier.ck").expect("checkpoint barrier");
        let zl = dag.find("barrier.zL").expect("zL barrier");
        let head = dag.find("head").expect("FP→BP barrier");
        for r in 0..2 {
            let fp_a = dag.find(&format!("fp.segA.row{r}")).unwrap();
            assert_eq!(dag.node(fp_a).kind, NodeKind::Row);
            assert!(dag.node(fp_a).deps.is_empty(), "OverL rows are edge-free");
            let fp_b = dag.find(&format!("fp.segB.row{r}")).unwrap();
            assert_eq!(dag.node(fp_b).deps, vec![ck], "segB row waits on ck only");
            let bp_b = dag.find(&format!("bp.segB.row{r}")).unwrap();
            assert!(dag.node(bp_b).deps.contains(&head), "BP waits for FP→BP");
        }
        assert_eq!(dag.node(head).deps, vec![zl]);
        assert_eq!(dag.node(head).kind, NodeKind::Barrier);
        let red_b = dag.find("barrier.bp.segB").unwrap();
        let bp_a0 = dag.find("bp.segA.row0").unwrap();
        assert_eq!(dag.node(bp_a0).deps, vec![red_b]);
        assert!(dag.find("barrier.bp.segA").is_some());
        // est_bytes come from the executable signatures
        let fp_a0 = dag.find("fp.segA.row0").unwrap();
        assert_eq!(dag.node(fp_a0).est_bytes, (5 * 4 + 4 * 4) * 4); // slab+z
        assert_eq!(dag.node(ck).est_bytes, 2 * 4 * 4 * 4); // zck

        // 2PS: rows exactly chain-shaped
        let plan = StepPlan::build(&man, Mode::Tps, &mut tracker).unwrap();
        let pipe = plan.lower(&man).unwrap();
        let dag = pipe.dag();
        assert!(dag.validate().is_ok());
        let r0 = dag.find("fp.tps.row0").unwrap();
        let r1 = dag.find("fp.tps.row1").unwrap();
        assert_eq!(dag.node(r0).kind, NodeKind::TpsRow);
        assert!(dag.node(r0).deps.is_empty());
        assert_eq!(dag.node(r1).deps, vec![r0], "2PS edges are a chain");
        let zl = dag.find("barrier.zL").unwrap();
        // the concat consumes every row's z, so zL depends on all rows
        // (the r0 edge is transitively implied by the chain; stating it
        // makes parked z grants release exactly at the concat)
        assert_eq!(dag.node(zl).deps, vec![r0, r1], "zL consumes every row");
        // 2PS row estimates include the staged boundary caches:
        // row0 = own 64 + outs (z 64 + 2×16) = 160;
        // row1 = own 64 + 2 caches in (2×16) + z 64 = 160
        assert_eq!(dag.node(r0).est_bytes, 160);
        assert_eq!(dag.node(r1).est_bytes, 160);

        // naive: rows edge-free, reduce gated on head
        let plan = StepPlan::build(&man, Mode::Naive, &mut tracker).unwrap();
        let pipe = plan.lower(&man).unwrap();
        let dag = pipe.dag();
        for r in 0..2 {
            let fp = dag.find(&format!("naive.fp.row{r}")).unwrap();
            assert!(dag.node(fp).deps.is_empty());
        }
        let head = dag.find("naive.head").unwrap();
        let red = dag.find("barrier.naive.reduce").unwrap();
        assert!(dag.node(red).deps.contains(&head));

        // Base: a single step node
        let plan = StepPlan::build(&man, Mode::Base, &mut tracker).unwrap();
        let pipe = plan.lower(&man).unwrap();
        assert_eq!(pipe.dag().len(), 1);
        assert_eq!(pipe.dag().find("base.step"), Some(0));
    }
}
