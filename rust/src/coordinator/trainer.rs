//! The live training loop over PJRT artifacts (Algorithm 1 realized).
//!
//! ## Step-plan architecture (docs/HOTPATH.md)
//!
//! All per-row bookkeeping that used to be re-derived every step — manifest
//! name formatting, `Segment`/`TpsPlan` clones, tracker-key strings — is
//! now computed **once** in [`StepPlan::build`] when the [`Trainer`] is
//! constructed: executable names resolve to integer [`ExecHandle`]s, row
//! intervals are copied out of the manifest, and every tracker buffer/phase
//! name is interned to a [`BufId`].  `Trainer::step` then walks the
//! prebuilt table performing **zero `format!`/`String` allocations** and,
//! thanks to [`TensorView`], zero input-slab copies.

use std::time::Instant;

use crate::data::SyntheticCorpus;
use crate::error::{Error, Result};
use crate::memory::{BufId, Tracker};
use crate::runtime::manifest::Manifest;
use crate::runtime::{ExecHandle, Runtime, Tensor, TensorView};

use super::{Optimizer, ParamSet};

/// Execution strategy for the live path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// column-centric single-executable step (the paper's Base)
    Base,
    /// OverL-H: segmented halo slabs, checkpoint after pool2
    RowHybrid,
    /// 2PS forward (boundary caches handed between rows) + row-slab BP
    Tps,
    /// broken w/o-sharing ablation (Fig. 11's diverging branch)
    Naive,
}

impl Mode {
    pub fn label(&self) -> &'static str {
        match self {
            Mode::Base => "Base",
            Mode::RowHybrid => "OverL-H",
            Mode::Tps => "2PS",
            Mode::Naive => "naive(w/o sharing)",
        }
    }
}

/// Per-step observability.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub loss: f32,
    /// coordinator-held activation bytes at the step's peak
    pub peak_bytes: u64,
    pub step_ms: f64,
    /// PJRT executions issued
    pub executions: u64,
}

/// Row extents for the naive equal-split ablation.
///
/// The AOT artifacts are compiled for *equal* slabs (`aot.py` asserts
/// `h % n_rows == 0`), so an uneven split is a planning error — the seed
/// code silently truncated the remainder rows instead, which both
/// under-trained and disagreed with the compiled shapes.
pub fn naive_row_extents(h: usize, n: usize) -> Result<Vec<[usize; 2]>> {
    if n == 0 || h == 0 {
        return Err(Error::InfeasiblePlan(format!(
            "naive split of H={h} into n={n} rows"
        )));
    }
    if h % n != 0 {
        return Err(Error::InfeasiblePlan(format!(
            "naive(w/o sharing) requires n | H: H={h}, n={n} leaves remainder {} — \
             the AOT artifacts are compiled for equal slabs",
            h % n
        )));
    }
    let rh = h / n;
    Ok((0..n).map(|r| [r * rh, (r + 1) * rh]).collect())
}

/// One row of a segment in the prebuilt execution table.
#[derive(Debug, Clone)]
struct RowPlan {
    fwd: ExecHandle,
    bwd: ExecHandle,
    in_iv: [usize; 2],
    out_iv: [usize; 2],
    fp_phase: BufId,   // "fp.{seg}.row{r}"
    bp_phase: BufId,   // "bp.{seg}.row{r}"
    slab_id: BufId,    // "fp.{seg}.slab{r}"
    z_id: BufId,       // "fp.{seg}.z{r}"
    bp_slab_id: BufId, // "bp.{seg}.slab{r}"
}

#[derive(Debug, Clone)]
struct SegPlan {
    param_lo: usize,
    param_hi: usize,
    rows: Vec<RowPlan>,
    out_id: BufId, // "fp.{seg}.out"
}

#[derive(Debug, Clone)]
struct TpsRowPlan {
    fwd: ExecHandle,
    own_iv: [usize; 2],
    phase: BufId,           // "fp.tps.row{r}"
    own_id: BufId,          // "tps.own{r}"
    z_id: BufId,            // "tps.z{r}"
    cache_ids: Vec<BufId>,  // "tps.cache{r}.{i}"
}

#[derive(Debug, Clone)]
struct TpsPlan {
    rows: Vec<TpsRowPlan>,
    zl_id: BufId, // "tps.zL"
}

#[derive(Debug, Clone)]
struct BasePlan {
    step: ExecHandle,
    fwd: ExecHandle,
    phase: BufId, // "base.step"
    n_conv: usize,
}

#[derive(Debug, Clone)]
struct HybridPlan {
    segs: Vec<SegPlan>, // [segA (below checkpoint), segB (above)]
    head: ExecHandle,
    head_phase: BufId, // "head"
    dzl_id: BufId,     // "dzL"
    dzck_id: BufId,    // "dzck"
    n_conv: usize,
    /// `Some` for [`Mode::Tps`]: forward runs 2PS over the full depth
    tps: Option<TpsPlan>,
}

#[derive(Debug, Clone)]
struct NaiveRowPlan {
    fwd: ExecHandle,
    bwd: ExecHandle,
    x_iv: [usize; 2],
    z_iv: [usize; 2],
}

#[derive(Debug, Clone)]
struct NaivePlan {
    rows: Vec<NaiveRowPlan>,
    head: ExecHandle,
    fp_phase: BufId, // "naive.fp"
    bp_phase: BufId, // "naive.bp"
    zl_id: BufId,    // "naive.zL"
    n_conv: usize,
}

#[derive(Debug, Clone)]
enum PlanKind {
    Base(BasePlan),
    Hybrid(HybridPlan),
    Naive(NaivePlan),
    /// The naive split is infeasible for this manifest (uneven rows); the
    /// error is surfaced at `step`/`forward` time so `Trainer` construction
    /// for the other modes is unaffected.
    NaiveInfeasible(String),
}

/// Prebuilt execution table for one [`Mode`]: everything `step` needs,
/// resolved once.
#[derive(Debug, Clone)]
pub struct StepPlan {
    kind: PlanKind,
}

impl StepPlan {
    /// Resolve executables, row geometry and tracker IDs for `mode`.
    /// String formatting and name lookup happen here — never in `step`.
    pub fn build(man: &Manifest, mode: Mode, tracker: &mut Tracker) -> Result<StepPlan> {
        let h = |name: &str| -> Result<ExecHandle> { man.index_of(name).map(ExecHandle) };
        let n_conv = man.model.n_conv_params;
        let kind = match mode {
            Mode::Base => PlanKind::Base(BasePlan {
                step: h("base_step")?,
                fwd: h("base_fwd")?,
                phase: tracker.intern("base.step"),
                n_conv,
            }),
            Mode::RowHybrid | Mode::Tps => {
                if man.plan.segments.len() != 2 {
                    return Err(Error::Artifact(format!(
                        "hybrid plan expects 2 segments, manifest has {}",
                        man.plan.segments.len()
                    )));
                }
                let mut segs = Vec::with_capacity(man.plan.segments.len());
                for seg in &man.plan.segments {
                    let mut rows = Vec::with_capacity(seg.rows.len());
                    for (r, row) in seg.rows.iter().enumerate() {
                        rows.push(RowPlan {
                            fwd: h(&format!("{}_row{r}_fwd", seg.name))?,
                            bwd: h(&format!("{}_row{r}_bwd", seg.name))?,
                            in_iv: row.in_iv,
                            out_iv: row.out_iv,
                            fp_phase: tracker.intern(format!("fp.{}.row{r}", seg.name)),
                            bp_phase: tracker.intern(format!("bp.{}.row{r}", seg.name)),
                            slab_id: tracker.intern(format!("fp.{}.slab{r}", seg.name)),
                            z_id: tracker.intern(format!("fp.{}.z{r}", seg.name)),
                            bp_slab_id: tracker.intern(format!("bp.{}.slab{r}", seg.name)),
                        });
                    }
                    segs.push(SegPlan {
                        param_lo: seg.param_lo,
                        param_hi: seg.param_hi,
                        rows,
                        out_id: tracker.intern(format!("fp.{}.out", seg.name)),
                    });
                }
                let tps = if mode == Mode::Tps {
                    let mut rows = Vec::with_capacity(man.plan.tps.rows.len());
                    for (r, row) in man.plan.tps.rows.iter().enumerate() {
                        let fwd = h(&format!("tps_row{r}_fwd"))?;
                        // outputs are [z, caches...]: cache count from the
                        // executable signature, ids interned up front
                        let n_caches =
                            man.executables[fwd.index()].outputs.len().saturating_sub(1);
                        rows.push(TpsRowPlan {
                            fwd,
                            own_iv: row.own_iv,
                            phase: tracker.intern(format!("fp.tps.row{r}")),
                            own_id: tracker.intern(format!("tps.own{r}")),
                            z_id: tracker.intern(format!("tps.z{r}")),
                            cache_ids: (0..n_caches)
                                .map(|i| tracker.intern(format!("tps.cache{r}.{i}")))
                                .collect(),
                        });
                    }
                    Some(TpsPlan {
                        rows,
                        zl_id: tracker.intern("tps.zL"),
                    })
                } else {
                    None
                };
                PlanKind::Hybrid(HybridPlan {
                    segs,
                    head: h("head")?,
                    head_phase: tracker.intern("head"),
                    dzl_id: tracker.intern("dzL"),
                    dzck_id: tracker.intern("dzck"),
                    n_conv,
                    tps,
                })
            }
            Mode::Naive => {
                let n = man.plan.naive_rows;
                let z_h = man.model.heights.last().copied().unwrap_or(0);
                match (
                    naive_row_extents(man.model.h, n),
                    naive_row_extents(z_h, n),
                ) {
                    (Ok(x_ivs), Ok(z_ivs)) => {
                        let mut rows = Vec::with_capacity(n);
                        for r in 0..n {
                            rows.push(NaiveRowPlan {
                                fwd: h(&format!("naive_row{r}_fwd"))?,
                                bwd: h(&format!("naive_row{r}_bwd"))?,
                                x_iv: x_ivs[r],
                                z_iv: z_ivs[r],
                            });
                        }
                        PlanKind::Naive(NaivePlan {
                            rows,
                            head: h("head")?,
                            fp_phase: tracker.intern("naive.fp"),
                            bp_phase: tracker.intern("naive.bp"),
                            zl_id: tracker.intern("naive.zL"),
                            n_conv,
                        })
                    }
                    (Err(e), _) | (_, Err(e)) => PlanKind::NaiveInfeasible(e.to_string()),
                }
            }
        };
        Ok(StepPlan { kind })
    }

    /// Every executable the plan will run — what the trainer warm-compiles
    /// at construction.
    pub fn handles(&self) -> Vec<ExecHandle> {
        let mut out = Vec::new();
        match &self.kind {
            PlanKind::Base(bp) => out.extend([bp.step, bp.fwd]),
            PlanKind::Hybrid(hp) => {
                for seg in &hp.segs {
                    for rp in &seg.rows {
                        out.push(rp.fwd);
                        out.push(rp.bwd);
                    }
                }
                if let Some(tp) = &hp.tps {
                    for rp in &tp.rows {
                        out.push(rp.fwd);
                    }
                }
                out.push(hp.head);
            }
            PlanKind::Naive(np) => {
                for rp in &np.rows {
                    out.push(rp.fwd);
                    out.push(rp.bwd);
                }
                out.push(np.head);
            }
            PlanKind::NaiveInfeasible(_) => {}
        }
        out
    }
}

/// Row-centric trainer over an artifact bundle.
pub struct Trainer<'r> {
    pub rt: &'r Runtime,
    pub params: ParamSet,
    pub optimizer: Optimizer,
    /// Fixed at construction: the [`StepPlan`] is built for this mode, so
    /// the field is read-only (swapping modes means a new `Trainer`).
    mode: Mode,
    pub tracker: Tracker,
    plan: StepPlan,
}

impl<'r> Trainer<'r> {
    pub fn new(rt: &'r Runtime, mode: Mode, lr: f32, seed: u64) -> Result<Trainer<'r>> {
        Trainer::with_optimizer(rt, mode, Optimizer::sgd(lr), seed)
    }

    /// Use a stateful optimizer (momentum/Adam); its state bytes belong to
    /// ξ in the planners' accounting (`Optimizer::state_bytes`).
    ///
    /// Builds the mode's [`StepPlan`] here — executable resolution, row
    /// geometry and tracker-ID interning all happen once, not per step.
    pub fn with_optimizer(
        rt: &'r Runtime,
        mode: Mode,
        optimizer: Optimizer,
        seed: u64,
    ) -> Result<Trainer<'r>> {
        let params = ParamSet::init(&rt.manifest.model, seed);
        let mut tracker = Tracker::new();
        let plan = StepPlan::build(&rt.manifest, mode, &mut tracker)?;
        // warm start: compile every executable the plan references now, so
        // no step (and no step timing) ever includes a first-use compile
        for h in plan.handles() {
            rt.ensure_compiled_h(h)?;
        }
        Ok(Trainer {
            rt,
            params,
            optimizer,
            mode,
            tracker,
            plan,
        })
    }

    /// The execution mode the step plan was built for.
    pub fn mode(&self) -> Mode {
        self.mode
    }

    /// One training step on (x, y); returns the loss.
    pub fn step(&mut self, x: &Tensor, y1h: &Tensor) -> Result<StepStats> {
        let t0 = Instant::now();
        let exec0 = self.rt.stats().executions;
        // activation buffers are strictly per-step; start a fresh ledger
        // (the interner survives — plan BufIds stay valid)
        self.tracker.reset();
        let (loss, grads) = match &self.plan.kind {
            PlanKind::Base(bp) => {
                Self::step_base(self.rt, &self.params, &mut self.tracker, bp, x, y1h)?
            }
            PlanKind::Hybrid(hp) => {
                Self::step_hybrid(self.rt, &self.params, &mut self.tracker, hp, x, y1h)?
            }
            PlanKind::Naive(np) => {
                Self::step_naive(self.rt, &self.params, &mut self.tracker, np, x, y1h)?
            }
            PlanKind::NaiveInfeasible(msg) => return Err(Error::InfeasiblePlan(msg.clone())),
        };
        self.optimizer.step(&mut self.params, &grads)?;
        Ok(StepStats {
            loss,
            peak_bytes: self.tracker.peak(),
            step_ms: t0.elapsed().as_secs_f64() * 1e3,
            executions: self.rt.stats().executions - exec0,
        })
    }

    /// Forward-only pass producing z^L (used by tests + quickstart).
    pub fn forward(&mut self, x: &Tensor) -> Result<Tensor> {
        self.tracker.reset();
        match &self.plan.kind {
            PlanKind::Base(bp) => {
                let mut args: Vec<TensorView> = Vec::with_capacity(1 + bp.n_conv);
                args.push(x.view());
                args.extend(self.params.tensors[..bp.n_conv].iter().map(|t| t.view()));
                Ok(self.rt.execute_h(bp.fwd, &args)?.remove(0))
            }
            PlanKind::Hybrid(hp) => match &hp.tps {
                Some(tp) => {
                    Self::tps_fp(self.rt, &self.params, &mut self.tracker, tp, hp.n_conv, x)
                }
                None => {
                    let zck = Self::segment_fp(
                        self.rt,
                        &self.params,
                        &mut self.tracker,
                        &hp.segs[0],
                        x,
                    )?;
                    Self::segment_fp(self.rt, &self.params, &mut self.tracker, &hp.segs[1], &zck)
                }
            },
            PlanKind::Naive(np) => Self::naive_fp(self.rt, &self.params, np, x),
            PlanKind::NaiveInfeasible(msg) => Err(Error::InfeasiblePlan(msg.clone())),
        }
    }

    // ---------------- Base ----------------

    fn step_base(
        rt: &Runtime,
        params: &ParamSet,
        tracker: &mut Tracker,
        bp: &BasePlan,
        x: &Tensor,
        y1h: &Tensor,
    ) -> Result<(f32, Vec<Tensor>)> {
        tracker.mark_id(bp.phase);
        let mut args: Vec<TensorView> = Vec::with_capacity(2 + params.tensors.len());
        args.push(x.view());
        args.push(y1h.view());
        args.extend(params.tensors.iter().map(|t| t.view()));
        let mut out = rt.execute_h(bp.step, &args)?;
        let grads = out.split_off(1);
        let loss = out[0].data[0];
        Ok((loss, grads))
    }

    // ---------------- OverL-H (and 2PS-fwd variant) ----------------

    /// FP of one segment, row by row; returns the concatenated output.
    fn segment_fp(
        rt: &Runtime,
        params: &ParamSet,
        tracker: &mut Tracker,
        seg: &SegPlan,
        input: &Tensor,
    ) -> Result<Tensor> {
        let seg_params = &params.tensors[seg.param_lo..seg.param_hi];
        let mut rows: Vec<Tensor> = Vec::with_capacity(seg.rows.len());
        for rp in &seg.rows {
            tracker.mark_id(rp.fp_phase);
            // zero-copy: a strided view, gathered only at the literal boundary
            let slab = input.slice_h(rp.in_iv[0], rp.in_iv[1])?;
            tracker.alloc_id(rp.slab_id, slab.size_bytes());
            let z = {
                let mut args: Vec<TensorView> = Vec::with_capacity(1 + seg_params.len());
                args.push(slab);
                args.extend(seg_params.iter().map(|t| t.view()));
                rt.execute_h(rp.fwd, &args)?.remove(0)
            };
            tracker.alloc_id(rp.z_id, z.size_bytes());
            // the input slab is released as soon as the row is done —
            // the row-centric memory reuse (Algorithm 1 line 9)
            tracker.free_id(rp.slab_id);
            rows.push(z);
        }
        let out = {
            let views: Vec<TensorView> = rows.iter().map(|t| t.view()).collect();
            Tensor::concat_h(&views)?
        };
        tracker.alloc_id(seg.out_id, out.size_bytes());
        for rp in &seg.rows {
            tracker.free_id(rp.z_id);
        }
        Ok(out)
    }

    /// 2PS forward over the full depth (N = tps_rows), caches handed
    /// row-to-row exactly as §IV-A describes.
    fn tps_fp(
        rt: &Runtime,
        params: &ParamSet,
        tracker: &mut Tracker,
        tp: &TpsPlan,
        n_conv: usize,
        x: &Tensor,
    ) -> Result<Tensor> {
        let conv = &params.tensors[..n_conv];
        let mut rows: Vec<Tensor> = Vec::with_capacity(tp.rows.len());
        let mut caches: Vec<Tensor> = Vec::new();
        for (r, rp) in tp.rows.iter().enumerate() {
            tracker.mark_id(rp.phase);
            let own = x.slice_h(rp.own_iv[0], rp.own_iv[1])?;
            tracker.alloc_id(rp.own_id, own.size_bytes());
            let mut out = {
                let mut args: Vec<TensorView> =
                    Vec::with_capacity(1 + caches.len() + conv.len());
                args.push(own);
                args.extend(caches.iter().map(|t| t.view())); // from row r−1
                args.extend(conv.iter().map(|t| t.view()));
                rt.execute_h(rp.fwd, &args)?
            };
            let z = out.remove(0);
            // free consumed caches, keep newly produced ones
            if r > 0 {
                for id in &tp.rows[r - 1].cache_ids {
                    tracker.free_id(*id);
                }
            }
            caches = out;
            debug_assert_eq!(caches.len(), rp.cache_ids.len());
            for (id, c) in rp.cache_ids.iter().zip(&caches) {
                tracker.alloc_id(*id, c.size_bytes());
            }
            tracker.alloc_id(rp.z_id, z.size_bytes());
            tracker.free_id(rp.own_id);
            rows.push(z);
        }
        if let Some(last) = tp.rows.last() {
            for id in &last.cache_ids {
                tracker.free_id(*id);
            }
        }
        let z_l = {
            let views: Vec<TensorView> = rows.iter().map(|t| t.view()).collect();
            Tensor::concat_h(&views)?
        };
        tracker.alloc_id(tp.zl_id, z_l.size_bytes());
        for rp in &tp.rows {
            tracker.free_id(rp.z_id);
        }
        Ok(z_l)
    }

    /// Shared head + row-wise BP for the hybrid and 2PS modes.
    fn step_hybrid(
        rt: &Runtime,
        params: &ParamSet,
        tracker: &mut Tracker,
        hp: &HybridPlan,
        x: &Tensor,
        y1h: &Tensor,
    ) -> Result<(f32, Vec<Tensor>)> {
        let seg_a = &hp.segs[0];
        let seg_b = &hp.segs[1];
        // ---- FP ----
        let zck = Self::segment_fp(rt, params, tracker, seg_a, x)?; // checkpoint
        let (z_l, zl_id) = match &hp.tps {
            // 2PS forward recomputes from the input; the checkpoint is
            // still produced for BP (2PS-H keeps checkpoints too)
            Some(tp) => (Self::tps_fp(rt, params, tracker, tp, hp.n_conv, x)?, tp.zl_id),
            None => (
                Self::segment_fp(rt, params, tracker, seg_b, &zck)?,
                seg_b.out_id,
            ),
        };
        // ---- head ----
        tracker.mark_id(hp.head_phase);
        let loss_out = rt.execute_h(
            hp.head,
            &[
                z_l.view(),
                y1h.view(),
                params.tensors[hp.n_conv].view(),
                params.tensors[hp.n_conv + 1].view(),
            ],
        )?;
        let loss = loss_out[0].data[0];
        let dz_l = &loss_out[1];
        tracker.alloc_id(hp.dzl_id, dz_l.size_bytes());
        // z^L consumed by the head
        tracker.free_id(zl_id);

        let mut grads = params.grad_zeros();
        let n_conv = hp.n_conv;
        grads[n_conv] = loss_out[2].clone(); // dWfc
        grads[n_conv + 1] = loss_out[3].clone(); // dbfc

        // ---- BP segment B (rows reversed; recompute inside row_bwd) ----
        let seg_b_params = &params.tensors[seg_b.param_lo..seg_b.param_hi];
        let mut dz_ck = Tensor::zeros(&zck.shape);
        tracker.alloc_id(hp.dzck_id, dz_ck.size_bytes());
        for rp in seg_b.rows.iter().rev() {
            tracker.mark_id(rp.bp_phase);
            let slab = zck.slice_h(rp.in_iv[0], rp.in_iv[1])?;
            let dz = dz_l.slice_h(rp.out_iv[0], rp.out_iv[1])?;
            tracker.alloc_id(rp.bp_slab_id, slab.size_bytes() + dz.size_bytes());
            let mut out = {
                let mut args: Vec<TensorView> = Vec::with_capacity(2 + seg_b_params.len());
                args.push(slab);
                args.extend(seg_b_params.iter().map(|t| t.view()));
                args.push(dz);
                rt.execute_h(rp.bwd, &args)?
            };
            let _z = out.pop().expect("bwd returns recomputed z last");
            let dx = out.pop().expect("segB bwd returns dx before z");
            for (i, g) in out.into_iter().enumerate() {
                grads[seg_b.param_lo + i].axpy(1.0, &g)?;
            }
            // overlapping slab input-gradients accumulate by linearity
            dz_ck.add_h(rp.in_iv[0], &dx)?;
            tracker.free_id(rp.bp_slab_id);
        }
        tracker.free_id(hp.dzl_id);

        // ---- BP segment A ----
        let seg_a_params = &params.tensors[seg_a.param_lo..seg_a.param_hi];
        for rp in seg_a.rows.iter().rev() {
            tracker.mark_id(rp.bp_phase);
            let slab = x.slice_h(rp.in_iv[0], rp.in_iv[1])?;
            let dz = dz_ck.slice_h(rp.out_iv[0], rp.out_iv[1])?;
            tracker.alloc_id(rp.bp_slab_id, slab.size_bytes() + dz.size_bytes());
            let mut out = {
                let mut args: Vec<TensorView> = Vec::with_capacity(2 + seg_a_params.len());
                args.push(slab);
                args.extend(seg_a_params.iter().map(|t| t.view()));
                args.push(dz);
                rt.execute_h(rp.bwd, &args)?
            };
            out.pop().expect("bwd returns recomputed z last");
            for (i, g) in out.into_iter().enumerate() {
                grads[seg_a.param_lo + i].axpy(1.0, &g)?;
            }
            tracker.free_id(rp.bp_slab_id);
        }
        tracker.free_id(hp.dzck_id);
        tracker.free_id(seg_a.out_id); // checkpoint consumed
        Ok((loss, grads))
    }

    // ---------------- naive (w/o sharing) ----------------

    /// Naive FP does no per-row tracking (seed parity: the ablation only
    /// accounts at the step level), hence no tracker parameter.
    fn naive_fp(rt: &Runtime, params: &ParamSet, np: &NaivePlan, x: &Tensor) -> Result<Tensor> {
        let conv = &params.tensors[..np.n_conv];
        let mut rows = Vec::with_capacity(np.rows.len());
        for rp in &np.rows {
            let slab = x.slice_h(rp.x_iv[0], rp.x_iv[1])?;
            let mut args: Vec<TensorView> = Vec::with_capacity(1 + conv.len());
            args.push(slab);
            args.extend(conv.iter().map(|t| t.view()));
            rows.push(rt.execute_h(rp.fwd, &args)?.remove(0));
        }
        let views: Vec<TensorView> = rows.iter().map(|t| t.view()).collect();
        Tensor::concat_h(&views)
    }

    fn step_naive(
        rt: &Runtime,
        params: &ParamSet,
        tracker: &mut Tracker,
        np: &NaivePlan,
        x: &Tensor,
        y1h: &Tensor,
    ) -> Result<(f32, Vec<Tensor>)> {
        tracker.mark_id(np.fp_phase);
        let z_l = Self::naive_fp(rt, params, np, x)?;
        tracker.alloc_id(np.zl_id, z_l.size_bytes());
        let loss_out = rt.execute_h(
            np.head,
            &[
                z_l.view(),
                y1h.view(),
                params.tensors[np.n_conv].view(),
                params.tensors[np.n_conv + 1].view(),
            ],
        )?;
        let loss = loss_out[0].data[0];
        let dz_l = &loss_out[1];
        let mut grads = params.grad_zeros();
        grads[np.n_conv] = loss_out[2].clone();
        grads[np.n_conv + 1] = loss_out[3].clone();
        tracker.mark_id(np.bp_phase);
        let conv_n = np.n_conv;
        for rp in np.rows.iter().rev() {
            let slab = x.slice_h(rp.x_iv[0], rp.x_iv[1])?;
            let dz = dz_l.slice_h(rp.z_iv[0], rp.z_iv[1])?;
            let mut out = {
                let mut args: Vec<TensorView> = Vec::with_capacity(2 + conv_n);
                args.push(slab);
                args.extend(params.tensors[..conv_n].iter().map(|t| t.view()));
                args.push(dz);
                rt.execute_h(rp.bwd, &args)?
            };
            out.pop().expect("bwd returns recomputed z last");
            for (i, g) in out.into_iter().enumerate() {
                grads[i].axpy(1.0, &g)?;
            }
        }
        tracker.free_id(np.zl_id);
        Ok((loss, grads))
    }
}

/// Convenience: train `steps` steps on the synthetic corpus; returns the
/// per-step losses.
pub fn train_loop(
    trainer: &mut Trainer<'_>,
    corpus: &SyntheticCorpus,
    steps: u64,
    log_every: u64,
) -> Result<Vec<f32>> {
    let b = trainer.rt.manifest.model.batch;
    let mut losses = Vec::with_capacity(steps as usize);
    for s in 0..steps {
        let (x, y, _) = corpus.batch(s, b);
        let stats = trainer.step(&x, &y)?;
        if log_every > 0 && s % log_every == 0 {
            println!(
                "  [{}] step {s:4}  loss {:.4}  peak {:>9}  {:.1} ms  {} execs",
                trainer.mode().label(),
                stats.loss,
                crate::metrics::fmt_bytes(stats.peak_bytes),
                stats.step_ms,
                stats.executions
            );
        }
        if !stats.loss.is_finite() {
            return Err(Error::Runtime(format!(
                "loss diverged to {} at step {s}",
                stats.loss
            )));
        }
        losses.push(stats.loss);
    }
    Ok(losses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_row_extents_equal_split() {
        let ivs = naive_row_extents(32, 4).unwrap();
        assert_eq!(ivs.len(), 4);
        assert_eq!(ivs[0], [0, 8]);
        assert_eq!(ivs[3], [24, 32]);
        // cover the full range with no gaps
        for w in ivs.windows(2) {
            assert_eq!(w[0][1], w[1][0]);
        }
    }

    #[test]
    fn naive_row_extents_rejects_remainder() {
        // the seed silently truncated h=33 n=4 to 4×8 rows, dropping row 32
        let err = naive_row_extents(33, 4).unwrap_err();
        match err {
            Error::InfeasiblePlan(msg) => {
                assert!(msg.contains("remainder"), "{msg}");
            }
            other => panic!("expected InfeasiblePlan, got {other:?}"),
        }
        assert!(naive_row_extents(8, 0).is_err());
        assert!(naive_row_extents(0, 2).is_err());
    }

    /// A miniature manifest with every executable the four modes resolve.
    fn plan_manifest(h: usize, naive_rows: usize) -> Manifest {
        let exes = [
            ("base_step", 2),
            ("base_fwd", 1),
            ("head", 4),
            ("segA_row0_fwd", 1),
            ("segA_row0_bwd", 3),
            ("segA_row1_fwd", 1),
            ("segA_row1_bwd", 3),
            ("segB_row0_fwd", 1),
            ("segB_row0_bwd", 4),
            ("segB_row1_fwd", 1),
            ("segB_row1_bwd", 4),
            ("tps_row0_fwd", 3), // z + 2 caches
            ("tps_row1_fwd", 1), // z only (last row)
            ("naive_row0_fwd", 1),
            ("naive_row0_bwd", 3),
            ("naive_row1_fwd", 1),
            ("naive_row1_bwd", 3),
        ];
        let exe_json: Vec<String> = exes
            .iter()
            .map(|(name, outs)| {
                let outputs: Vec<&str> = (0..*outs).map(|_| "[1]").collect();
                format!(
                    r#"{{"name": "{name}", "path": "{name}.hlo", "kind": "k",
                         "inputs": [], "outputs": [{}]}}"#,
                    outputs.join(", ")
                )
            })
            .collect();
        let seg = |name: &str| {
            format!(
                r#"{{"name": "{name}", "h_in": {h}, "h_out": {h}, "c_in": 1, "c_out": 1,
                     "param_lo": 0, "param_hi": 2,
                     "rows": [
                       {{"out_iv": [0, 4], "in_iv": [0, 5], "chain": []}},
                       {{"out_iv": [4, 8], "in_iv": [3, 8], "chain": []}}
                     ]}}"#
            )
        };
        let text = format!(
            r#"{{
              "model": {{
                "name": "t", "batch": 1, "h": {h}, "w": 8, "n_classes": 2,
                "layers": [], "heights": [{h}, {h}], "w_out": 8, "fc_in": 4,
                "param_shapes": [[1, 1, 3, 3], [1], [4, 2], [2]],
                "n_conv_params": 2
              }},
              "plan": {{
                "ckpt_split": 1, "n_rows": 2, "tps_rows": 2, "naive_rows": {naive_rows},
                "segments": [{segA}, {segB}],
                "tps": {{
                  "cuts": [0, 4, 8],
                  "rows": [
                    {{"own_iv": [0, 4], "bounds": [[0, 4]], "cache_in": [null], "cache_out": [[3, 4]]}},
                    {{"own_iv": [4, 8], "bounds": [[4, 8]], "cache_in": [[3, 4]], "cache_out": [null]}}
                  ]
                }}
              }},
              "executables": [{exes}]
            }}"#,
            segA = seg("segA"),
            segB = seg("segB"),
            exes = exe_json.join(",\n")
        );
        Manifest::parse(&text).expect("test manifest parses")
    }

    #[test]
    fn step_plan_interns_everything_up_front() {
        let man = plan_manifest(8, 2);
        for mode in [Mode::Base, Mode::RowHybrid, Mode::Tps, Mode::Naive] {
            let mut tracker = Tracker::new();
            let plan = StepPlan::build(&man, mode, &mut tracker).unwrap();
            match (&plan.kind, mode) {
                (PlanKind::Base(bp), Mode::Base) => {
                    assert_eq!(bp.step.index(), man.index_of("base_step").unwrap());
                    assert_eq!(bp.fwd.index(), man.index_of("base_fwd").unwrap());
                    assert_eq!(bp.n_conv, 2);
                }
                (PlanKind::Hybrid(hp), Mode::RowHybrid) => {
                    assert!(hp.tps.is_none());
                    assert_eq!(hp.segs.len(), 2);
                    assert_eq!(hp.segs[0].rows.len(), 2);
                    let rp = &hp.segs[1].rows[1];
                    assert_eq!(rp.fwd.index(), man.index_of("segB_row1_fwd").unwrap());
                    assert_eq!(rp.bwd.index(), man.index_of("segB_row1_bwd").unwrap());
                    assert_eq!(rp.in_iv, [3, 8]);
                    assert_eq!(rp.out_iv, [4, 8]);
                    // ids resolve to the exact strings the seed allocated,
                    // so tracker accounting stays byte-identical
                    assert_eq!(tracker.name(rp.slab_id), "fp.segB.slab1");
                    assert_eq!(tracker.name(rp.bp_slab_id), "bp.segB.slab1");
                    assert_eq!(tracker.name(hp.segs[1].out_id), "fp.segB.out");
                    assert_eq!(tracker.name(hp.dzl_id), "dzL");
                }
                (PlanKind::Hybrid(hp), Mode::Tps) => {
                    let tp = hp.tps.as_ref().expect("2PS plan");
                    assert_eq!(tp.rows.len(), 2);
                    // cache count derived from the executable signature
                    assert_eq!(tp.rows[0].cache_ids.len(), 2);
                    assert_eq!(tp.rows[1].cache_ids.len(), 0);
                    assert_eq!(tracker.name(tp.rows[0].cache_ids[1]), "tps.cache0.1");
                    assert_eq!(tracker.name(tp.zl_id), "tps.zL");
                }
                (PlanKind::Naive(np), Mode::Naive) => {
                    assert_eq!(np.rows.len(), 2);
                    assert_eq!(np.rows[0].x_iv, [0, 4]);
                    assert_eq!(np.rows[1].x_iv, [4, 8]);
                    assert_eq!(np.rows[1].z_iv, [4, 8]);
                }
                (kind, mode) => panic!("unexpected plan {kind:?} for {mode:?}"),
            }
        }
    }

    #[test]
    fn step_plan_flags_uneven_naive_split() {
        // h=8, naive_rows=3: 8 % 3 != 0 — the seed truncated, we flag
        let man = plan_manifest(8, 3);
        let mut tracker = Tracker::new();
        let plan = StepPlan::build(&man, Mode::Naive, &mut tracker).unwrap();
        match &plan.kind {
            PlanKind::NaiveInfeasible(msg) => assert!(msg.contains("remainder"), "{msg}"),
            other => panic!("expected NaiveInfeasible, got {other:?}"),
        }
        // the other modes are unaffected by the naive split
        assert!(StepPlan::build(&man, Mode::RowHybrid, &mut tracker).is_ok());
    }

    #[test]
    fn step_plan_errors_on_missing_executable() {
        let mut man = plan_manifest(8, 2);
        man.executables.retain(|e| e.name != "segB_row1_bwd");
        let mut tracker = Tracker::new();
        match StepPlan::build(&man, Mode::RowHybrid, &mut tracker) {
            Err(Error::Artifact(msg)) => assert!(msg.contains("segB_row1_bwd"), "{msg}"),
            other => panic!("expected Artifact error, got {:?}", other.is_ok()),
        }
    }
}
