//! The live training loop over PJRT artifacts (Algorithm 1 realized).
//!
//! ## Step-plan architecture (docs/HOTPATH.md)
//!
//! All per-row bookkeeping that used to be re-derived every step —
//! manifest name formatting, `Segment`/`TpsPlan` clones — is computed
//! **once** in [`StepPlan::build`] when the [`Trainer`] is constructed:
//! executable names resolve to integer [`ExecHandle`]s and row intervals
//! are copied out of the manifest.  Every step then walks prebuilt tables
//! performing **zero `format!`/`String` allocations** and, thanks to
//! [`TensorView`], zero input-slab copies.
//!
//! ## One program, three drivers (docs/ROWIR.md)
//!
//! The step's dataflow is encoded exactly once: `rowir::lower` compiles
//! the manifest + [`Mode`] into a [`RowProgram`] whose nodes carry their
//! [`Task`]s.  The trainer is a set of *drivers* over that program:
//!
//! * [`sched::Policy::Serial`] — [`StepPlan::step_serial`] runs the
//!   `rowir::interp` interpreter: nodes execute in ascending `NodeId`
//!   order on the caller's thread.  This **is** the serial schedule;
//!   there is no hand-written serial step path anymore.
//! * [`sched::Policy::Pipelined`] — [`StepPlan::step_pipelined`] runs the
//!   same program on a worker pool under memory admission (`sched::run`),
//!   or on the persistent multi-device pool when a [`ShardState`] is
//!   configured.
//!
//! Results are **bit-identical** across drivers by construction: every
//! driver dispatches the same tasks to the same handlers; per-row
//! handlers write [`Slot`]s, and every floating-point reduction (gradient
//! accumulation, δ-accumulation, H-concat) happens inside a barrier task
//! that folds rows in the interpreter's (= id = serial) order.

use std::sync::Arc;
use std::time::Instant;

use crate::costmodel::{self, CostModel};
use crate::data::SyntheticCorpus;
use crate::error::{Error, Result};
use crate::faults::{DeviceLostPolicy, FaultConfig, FaultInjector};
use crate::memory::DeviceModel;
use crate::obs::{self, Recorder};
use crate::rowir::{self, interp, Graph, InterpOutcome, RowProgram, Task};
use crate::runtime::manifest::Manifest;
use crate::runtime::{ExecBackend, ExecHandle, Runtime, Tensor, TensorView};
use crate::sched::{self, ExecOutcome, Policy, RetryPolicy, SchedConfig, Slot, Trace};
use crate::shard::{
    self, DeviceId, FaultArgs, PartitionPolicy, ShardPlan, ShardedExecutor, StepRun, Topology,
};

pub use crate::rowir::{naive_row_extents, Mode};

use super::{Optimizer, ParamSet};

/// Per-step observability.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub loss: f32,
    /// Projected activation bytes at the step's peak, in the admission
    /// currency (working sets + parked handoff slots).  Serial: the
    /// interpreter's replay-ledger peak — exactly the single-device
    /// `memory::sim` replay of the program.  Pipelined: the admission
    /// ledger's peak; under sharding, the worst single-device peak.
    pub peak_bytes: u64,
    /// Per-device admission peaks (`vec![peak_bytes]` off the sharded
    /// path).
    pub device_peaks: Vec<u64>,
    pub step_ms: f64,
    /// PJRT executions issued
    pub executions: u64,
    /// Transient-fault retries absorbed this step (0 off the faulty path).
    pub retries: u64,
    /// Modeled backoff seconds those retries charged — attribution like
    /// `Topology::transfer_seconds`, never slept.
    pub modeled_backoff_s: f64,
    /// Devices lost (and degraded around) during this step.
    pub lost_devices: Vec<usize>,
    /// Sharded nodes re-executed by recovery phases after device losses.
    pub recomputed_nodes: u64,
    /// Max |EWMA relative error| across the drift monitor's
    /// (device, kind) cells after this step (0 when not recording).
    pub drift_max: f64,
    /// Drift cells past the relative-error threshold this step.
    pub drifting: u64,
    /// Devices flagged as busy-time stragglers this step.
    pub stragglers: Vec<usize>,
    /// The online loop refit the cost model after this step
    /// ([`Trainer::recalibrate_every`]).
    pub recalibrated: bool,
    /// The refit also swapped in a re-partitioned shard plan (guarded:
    /// never modeled slower than the stale plan).
    pub repartitioned: bool,
}

/// One row of a segment in the prebuilt execution table.
#[derive(Debug, Clone)]
struct RowPlan {
    fwd: ExecHandle,
    bwd: ExecHandle,
    in_iv: [usize; 2],
    out_iv: [usize; 2],
}

#[derive(Debug, Clone)]
struct SegPlan {
    param_lo: usize,
    param_hi: usize,
    rows: Vec<RowPlan>,
}

#[derive(Debug, Clone)]
struct TpsRowPlan {
    fwd: ExecHandle,
    own_iv: [usize; 2],
}

#[derive(Debug, Clone)]
struct TpsPlan {
    rows: Vec<TpsRowPlan>,
}

#[derive(Debug, Clone)]
struct BasePlan {
    step: ExecHandle,
    fwd: ExecHandle,
    n_conv: usize,
}

#[derive(Debug, Clone)]
struct HybridPlan {
    segs: Vec<SegPlan>, // [segA (below checkpoint), segB (above)]
    head: ExecHandle,
    n_conv: usize,
    /// `Some` for [`Mode::Tps`]: forward runs 2PS over the full depth
    tps: Option<TpsPlan>,
}

#[derive(Debug, Clone)]
struct NaiveRowPlan {
    fwd: ExecHandle,
    bwd: ExecHandle,
    x_iv: [usize; 2],
    z_iv: [usize; 2],
}

#[derive(Debug, Clone)]
struct NaivePlan {
    rows: Vec<NaiveRowPlan>,
    head: ExecHandle,
    n_conv: usize,
}

#[derive(Debug, Clone)]
enum PlanKind {
    Base(BasePlan),
    Hybrid(HybridPlan),
    Naive(NaivePlan),
    /// The naive split is infeasible for this manifest (uneven rows); the
    /// error is surfaced at `step`/`forward` time so `Trainer` construction
    /// for the other modes is unaffected.
    NaiveInfeasible(String),
}

/// Prebuilt execution table for one [`Mode`]: everything the task
/// handlers need (executables, row geometry, parameter ranges), resolved
/// once.  The *dataflow* is not here — that is the [`RowProgram`] the
/// `rowir` lowering emits; this table is what the program's tasks index
/// into.
#[derive(Debug, Clone)]
pub struct StepPlan {
    kind: PlanKind,
    mode: Mode,
}

impl StepPlan {
    /// Resolve executables and row geometry for `mode`.  String
    /// formatting and name lookup happen here — never on the step path.
    pub fn build(man: &Manifest, mode: Mode) -> Result<StepPlan> {
        let h = |name: &str| -> Result<ExecHandle> { man.index_of(name).map(ExecHandle) };
        let n_conv = man.model.n_conv_params;
        let kind = match mode {
            Mode::Base => PlanKind::Base(BasePlan {
                step: h("base_step")?,
                fwd: h("base_fwd")?,
                n_conv,
            }),
            Mode::RowHybrid | Mode::Tps => {
                if man.plan.segments.len() != 2 {
                    return Err(Error::Artifact(format!(
                        "hybrid plan expects 2 segments, manifest has {}",
                        man.plan.segments.len()
                    )));
                }
                let mut segs = Vec::with_capacity(man.plan.segments.len());
                for seg in &man.plan.segments {
                    let mut rows = Vec::with_capacity(seg.rows.len());
                    for (r, row) in seg.rows.iter().enumerate() {
                        rows.push(RowPlan {
                            fwd: h(&format!("{}_row{r}_fwd", seg.name))?,
                            bwd: h(&format!("{}_row{r}_bwd", seg.name))?,
                            in_iv: row.in_iv,
                            out_iv: row.out_iv,
                        });
                    }
                    segs.push(SegPlan {
                        param_lo: seg.param_lo,
                        param_hi: seg.param_hi,
                        rows,
                    });
                }
                let tps = if mode == Mode::Tps {
                    let mut rows = Vec::with_capacity(man.plan.tps.rows.len());
                    for (r, row) in man.plan.tps.rows.iter().enumerate() {
                        rows.push(TpsRowPlan {
                            fwd: h(&format!("tps_row{r}_fwd"))?,
                            own_iv: row.own_iv,
                        });
                    }
                    Some(TpsPlan { rows })
                } else {
                    None
                };
                PlanKind::Hybrid(HybridPlan {
                    segs,
                    head: h("head")?,
                    n_conv,
                    tps,
                })
            }
            Mode::Naive => {
                let n = man.plan.naive_rows;
                let z_h = man.model.heights.last().copied().unwrap_or(0);
                match (
                    naive_row_extents(man.model.h, n),
                    naive_row_extents(z_h, n),
                ) {
                    (Ok(x_ivs), Ok(z_ivs)) => {
                        let mut rows = Vec::with_capacity(n);
                        for r in 0..n {
                            rows.push(NaiveRowPlan {
                                fwd: h(&format!("naive_row{r}_fwd"))?,
                                bwd: h(&format!("naive_row{r}_bwd"))?,
                                x_iv: x_ivs[r],
                                z_iv: z_ivs[r],
                            });
                        }
                        PlanKind::Naive(NaivePlan {
                            rows,
                            head: h("head")?,
                            n_conv,
                        })
                    }
                    (Err(e), _) | (_, Err(e)) => PlanKind::NaiveInfeasible(e.to_string()),
                }
            }
        };
        Ok(StepPlan { kind, mode })
    }

    /// The mode this table (and its program) was built for.
    pub fn mode(&self) -> Mode {
        self.mode
    }

    /// Every executable the plan will run — what the trainer warm-compiles
    /// at construction.
    pub fn handles(&self) -> Vec<ExecHandle> {
        let mut out = Vec::new();
        match &self.kind {
            PlanKind::Base(bp) => out.extend([bp.step, bp.fwd]),
            PlanKind::Hybrid(hp) => {
                for seg in &hp.segs {
                    for rp in &seg.rows {
                        out.push(rp.fwd);
                        out.push(rp.bwd);
                    }
                }
                if let Some(tp) = &hp.tps {
                    for rp in &tp.rows {
                        out.push(rp.fwd);
                    }
                }
                out.push(hp.head);
            }
            PlanKind::Naive(np) => {
                for rp in &np.rows {
                    out.push(rp.fwd);
                    out.push(rp.bwd);
                }
                out.push(np.head);
            }
            PlanKind::NaiveInfeasible(_) => {}
        }
        out
    }

    /// Lower the plan's mode into its row program — a thin delegation to
    /// [`rowir::lower`], the single dataflow encoding.
    ///
    /// Errors with [`Error::InfeasiblePlan`] for a naive-infeasible plan.
    pub fn lower(&self, man: &Manifest) -> Result<RowProgram> {
        if let PlanKind::NaiveInfeasible(msg) = &self.kind {
            return Err(Error::InfeasiblePlan(msg.clone()));
        }
        rowir::lower(man, self.mode)
    }

    /// Handoff cells for one step of this plan.
    fn make_cells(&self) -> Result<Cells> {
        Ok(match &self.kind {
            PlanKind::Base(_) => Cells::Base(Slot::new()),
            PlanKind::Hybrid(hp) => Cells::Hybrid(HybridCells::new(hp)),
            PlanKind::Naive(np) => Cells::Naive(NaiveCells::new(np)),
            PlanKind::NaiveInfeasible(msg) => {
                return Err(Error::InfeasiblePlan(msg.clone()))
            }
        })
    }

    /// The serial driver: one training step by interpreting `program` in
    /// ascending `NodeId` order on the caller's thread (`rowir::interp`).
    /// This is the reference schedule the other drivers are bit-identical
    /// to.  Returns the loss, the gradients and the interpreter outcome
    /// (whose `peak_bytes` is the program's serial replay-ledger peak).
    pub fn step_serial(
        &self,
        ex: &dyn ExecBackend,
        program: &RowProgram,
        params: &ParamSet,
        x: &Tensor,
        y1h: &Tensor,
    ) -> Result<(f32, Vec<Tensor>, InterpOutcome)> {
        self.step_serial_recorded(ex, program, params, x, y1h, None)
    }

    /// [`StepPlan::step_serial`] with span recording: every interpreted
    /// node lands in `rec` as a worker-0/device-0 span (the serial driver
    /// has no admission ledger, so `in_flight_bytes` is 0).  Recording
    /// is strictly observational — node order and results are untouched.
    pub fn step_serial_recorded(
        &self,
        ex: &dyn ExecBackend,
        program: &RowProgram,
        params: &ParamSet,
        x: &Tensor,
        y1h: &Tensor,
        rec: Option<&Recorder>,
    ) -> Result<(f32, Vec<Tensor>, InterpOutcome)> {
        let cells = self.make_cells()?;
        let graph = program.graph();
        let outcome = interp::run(program, |id, task| {
            let t0 = rec.map(|r| r.now_ns());
            let out = run_task(ex, &self.kind, params, x, y1h, &cells, task);
            if let (Some(r), Some(start)) = (rec, t0) {
                let node = graph.node(id);
                r.push(
                    0,
                    obs::Span {
                        node: id,
                        kind: node.kind,
                        label: node.label.clone(),
                        device: 0,
                        worker: 0,
                        attempt: 1,
                        phase: r.phase(),
                        step: r.step(),
                        bytes: node.est_bytes,
                        in_flight_bytes: 0,
                        start_ns: start,
                        dur_ns: r.now_ns().saturating_sub(start),
                    },
                );
            }
            out
        })?;
        let (loss, grads) = take_result(&cells)?;
        Ok((loss, grads, outcome))
    }

    /// The pipelined/sharded driver: the same program on a worker pool
    /// under memory admission — the per-step `sched::run` scope without
    /// sharding, or the persistent [`ShardedExecutor`] (per-device
    /// ledgers, transfer nodes) when a [`ShardState`] is supplied.
    /// Bit-exact with [`StepPlan::step_serial`] either way: every
    /// reduction happens in a barrier task in id order; workers only
    /// produce per-row outputs, and transfers carry data, not arithmetic.
    ///
    /// The shard state is `&mut` because its [`ShardState::run_step`]
    /// owns the fault-recovery loop: a device loss re-partitions the
    /// plan in place before the step resumes.  Step results stay
    /// bit-identical to serial across recovery — every base node still
    /// runs exactly once, and its output lands in the same host slot.
    pub fn step_pipelined(
        &self,
        ex: &dyn ExecBackend,
        program: &RowProgram,
        params: &ParamSet,
        cfg: &SchedConfig,
        shard: Option<&mut ShardState>,
        x: &Tensor,
        y1h: &Tensor,
    ) -> Result<(f32, Vec<Tensor>, ExecOutcome)> {
        self.step_pipelined_recorded(ex, program, params, cfg, shard, x, y1h, None)
    }

    /// [`StepPlan::step_pipelined`] with span recording, threading `rec`
    /// into whichever pool runs the step (`sched::run_recorded` or
    /// [`ShardState::run_step_recorded`]).
    #[allow(clippy::too_many_arguments)]
    pub fn step_pipelined_recorded(
        &self,
        ex: &dyn ExecBackend,
        program: &RowProgram,
        params: &ParamSet,
        cfg: &SchedConfig,
        shard: Option<&mut ShardState>,
        x: &Tensor,
        y1h: &Tensor,
        rec: Option<&Recorder>,
    ) -> Result<(f32, Vec<Tensor>, ExecOutcome)> {
        let cells = self.make_cells()?;
        let outcome = match shard {
            Some(ss) => ss.run_step_recorded(rec, |task| {
                run_task(ex, &self.kind, params, x, y1h, &cells, task)
            }),
            None => {
                let graph = program.graph();
                sched::run_recorded(
                    graph,
                    cfg,
                    |id| {
                        run_task(ex, &self.kind, params, x, y1h, &cells, graph.node(id).task)
                    },
                    rec,
                )
            }
        }?;
        let (loss, grads) = take_result(&cells)?;
        Ok((loss, grads, outcome))
    }

    /// Forward-only pass producing z^L: interpret the z^L barrier's
    /// dependency closure — for 2PS that is the chain alone (the
    /// checkpoint half is skipped, exactly as the old hand-written
    /// forward path did) — and take the barrier's output.  The same
    /// handlers as a full step run, so the forward dataflow is not
    /// encoded a second time.  Base plans use the fused forward
    /// executable instead (no z^L barrier in their single-node program).
    pub fn forward_zl(
        &self,
        ex: &dyn ExecBackend,
        program: &RowProgram,
        params: &ParamSet,
        x: &Tensor,
    ) -> Result<Tensor> {
        let zl_task = match &self.kind {
            PlanKind::Hybrid(_) => Task::ZlBarrier,
            PlanKind::Naive(_) => Task::NaiveZl,
            PlanKind::Base(_) => {
                return Err(Error::Sched(
                    "forward_zl: base plans use the fused forward executable".into(),
                ))
            }
            PlanKind::NaiveInfeasible(msg) => {
                return Err(Error::InfeasiblePlan(msg.clone()))
            }
        };
        let zl = program
            .find_task(zl_task)
            .ok_or_else(|| Error::Sched("program has no z^L barrier".into()))?;
        let cells = self.make_cells()?;
        // FP tasks never read the labels; the head (their only consumer)
        // is outside the z^L closure
        let y_dummy = Tensor::zeros(&[1]);
        interp::run_closure(program, zl, |_, task| {
            run_task(ex, &self.kind, params, x, &y_dummy, &cells, task)
        })?;
        match &cells {
            Cells::Hybrid(c) => c.zl.take("zl"),
            Cells::Naive(c) => c.zl.take("naive.zl"),
            Cells::Base(_) => unreachable!("rejected above"),
        }
    }
}

/// Everything a device-loss recovery needs to re-plan from scratch:
/// the unlowered step graph plus the (surviving) topology and the
/// budget-shaping inputs `ShardState::build` used the first time.
struct RecoveryCtx {
    /// The base (pre-transfer-lowering) step graph.
    base: Graph,
    /// Live topology; `mark_failed` masks devices as they die, so device
    /// ids — and with them ledger/trace lanes — stay stable.
    topo: Topology,
    policy: PartitionPolicy,
    mem_budget: u64,
    xi: u64,
    /// Optimizer level the original plan was built with — recovery and
    /// recalibration rebuilds re-optimize at the same level, so a swap
    /// never silently changes the optimization story.
    opt_level: u8,
}

/// Fault-injection knobs installed on a shard state
/// ([`ShardState::set_faults`]); default is fault-free with no retry.
#[derive(Default)]
struct FaultState {
    injector: Option<FaultInjector>,
    retry: RetryPolicy,
    on_lost: DeviceLostPolicy,
}

/// Sharded execution state: the transfer-lowered plan plus the
/// persistent worker pool (constructed once in [`Trainer::set_sched`],
/// reused by every step — no spawn-per-step).
///
/// With a [`FaultConfig`] installed, [`ShardState::run_step`] also owns
/// the device-loss recovery loop: quiesce → mark the device failed →
/// re-partition over the survivors → re-run only the unfinished
/// dependency closure (docs/RESILIENCE.md).
pub struct ShardState {
    plan: ShardPlan,
    exec: ShardedExecutor,
    /// `None` for externally-built plans ([`ShardState::with_plan`]):
    /// without the base graph + topology a device loss cannot degrade
    /// and surfaces [`Error::DeviceLost`] directly.
    recovery: Option<RecoveryCtx>,
    faults: FaultState,
    /// Training-step counter the fault plan's schedule resolves against.
    step_no: u64,
    /// Devices lost during the most recent step.
    last_lost: Vec<DeviceId>,
    /// Sharded nodes re-executed by the most recent step's recovery
    /// phases.
    last_recomputed: u64,
    /// What `ShardPlan::optimize` did to the active plan (`None` when
    /// built at level 0 or via [`ShardState::with_plan`]).
    opt_report: Option<rowir::OptReport>,
}

/// Map a base-graph recompute closure onto a sharded plan: a real node
/// runs iff its originating base node is in the closure; a transfer runs
/// iff any of its consumers does (walked in descending id order —
/// consumers of a transfer are always real nodes with higher ids).
fn closure_on_plan(plan: &ShardPlan, closure: &[bool]) -> Vec<bool> {
    let n = plan.graph().len();
    let mut include = vec![false; n];
    for id in 0..n {
        if let Some(o) = plan.orig()[id] {
            include[id] = closure[o];
        }
    }
    for id in (0..n).rev() {
        if plan.orig()[id].is_none() {
            include[id] = plan.succ()[id].iter().any(|&s| include[s]);
        }
    }
    include
}

impl ShardState {
    /// Build the sharded execution state for one lowered program: the
    /// (possibly heterogeneous) `shard::Topology` from the config's
    /// device specs, per-device admission budgets clamped to what each device
    /// can actually hold (`min(cfg.mem_budget, usable HBM − ξ)` where ξ
    /// is the always-resident parameter + optimizer bytes), the
    /// partition + transfer lowering, and the persistent worker pool.
    ///
    /// Errors — leaving nothing half-built — when the partition is
    /// infeasible under the clamped ledgers **or** any device's
    /// serial-order replay peak exceeds its clamped budget: a plan that
    /// passes admission but overflows a small device's memory would OOM
    /// on real hardware, so it is rejected here, at configuration time.
    pub fn build(
        program: &RowProgram,
        cfg: &SchedConfig,
        xi: u64,
        opt_level: u8,
    ) -> Result<ShardState> {
        let sc = cfg.shard.clone().unwrap_or_else(|| shard::ShardConfig::new(1));
        let topo = sc.topology();
        let budgets: Vec<u64> = topo
            .budgets(xi)
            .into_iter()
            .map(|cap| cap.min(cfg.mem_budget))
            .collect();
        let mut plan = ShardPlan::build(program.graph(), &topo, sc.policy, budgets)?;
        // optimize post-lowering (coalescing must see the Transfer
        // nodes), then let the replay-based budget check remain the
        // admission authority over the optimized plan
        let opt_report = if opt_level > 0 {
            Some(plan.optimize(opt_level, &topo)?)
        } else {
            None
        };
        plan.check_budgets()?;
        Ok(ShardState {
            plan,
            exec: ShardedExecutor::new(cfg.workers),
            recovery: Some(RecoveryCtx {
                base: program.graph().clone(),
                topo,
                policy: sc.policy,
                mem_budget: cfg.mem_budget,
                xi,
                opt_level,
            }),
            faults: FaultState::default(),
            step_no: 0,
            last_lost: Vec::new(),
            last_recomputed: 0,
            opt_report,
        })
    }

    /// Wrap an externally-built shard plan (custom partition, custom —
    /// e.g. tight replay-ledger — budgets) with a fresh persistent pool.
    /// The proof suites drive exact-budget plans through this; the
    /// trainer path goes through [`ShardState::build`].  No recovery
    /// context: a device loss surfaces [`Error::DeviceLost`] directly.
    pub fn with_plan(plan: ShardPlan, workers: usize) -> ShardState {
        ShardState {
            plan,
            exec: ShardedExecutor::new(workers.max(1)),
            recovery: None,
            faults: FaultState::default(),
            step_no: 0,
            last_lost: Vec::new(),
            last_recomputed: 0,
            opt_report: None,
        }
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// What `ShardPlan::optimize` did to the active plan (`None` at
    /// level 0 or for externally-built plans).
    pub fn opt_report(&self) -> Option<&rowir::OptReport> {
        self.opt_report.as_ref()
    }

    /// Install fault-injection knobs (a fresh [`FaultInjector`] with its
    /// per-spec firing budgets reset).
    pub fn set_faults(&mut self, cfg: &FaultConfig) {
        self.faults = FaultState {
            injector: cfg.plan.clone().map(FaultInjector::new),
            retry: cfg.retry,
            on_lost: cfg.on_device_lost,
        };
    }

    /// The surviving topology (`None` for [`ShardState::with_plan`]).
    pub fn topology(&self) -> Option<&Topology> {
        self.recovery.as_ref().map(|c| &c.topo)
    }

    /// Devices lost during the most recent [`ShardState::run_step`].
    pub fn last_lost(&self) -> &[DeviceId] {
        &self.last_lost
    }

    /// Sharded nodes re-executed by the most recent step's recovery
    /// phases.
    pub fn last_recomputed(&self) -> u64 {
        self.last_recomputed
    }

    /// One step under the installed fault knobs, including the
    /// device-loss recovery loop:
    ///
    /// 1. run the current include subset ([`ShardedExecutor::run_step_faulty`]);
    /// 2. on [`StepRun::Lost`]: fold the finished frontier back onto the
    ///    base graph (outputs live in host slots and survive), mark the
    ///    device failed, re-partition over the survivors
    ///    ([`ShardPlan::build`] — failed devices have zero budget and
    ///    take no nodes), and restrict the next phase to the
    ///    [`interp::recompute_closure`] of the unfinished base nodes,
    ///    gated by [`ShardPlan::check_budgets_subset`];
    /// 3. repeat until [`StepRun::Done`]; peaks merge elementwise (device
    ///    ids are stable across phases), retry/backoff accounting sums,
    ///    and the returned trace is the final phase's.
    ///
    /// Every base node still executes exactly once across all phases, so
    /// results remain bit-identical to serial.  [`Error::DeviceLost`]
    /// surfaces when the policy is [`DeviceLostPolicy::Fail`], there is
    /// no recovery context, no device survives, or no survivor layout is
    /// ledger-feasible.
    pub fn run_step<F>(&mut self, run: F) -> Result<ExecOutcome>
    where
        F: Fn(Task) -> Result<()> + Sync,
    {
        self.run_step_recorded(None, run)
    }

    /// [`ShardState::run_step`] with span recording: every dispatch of
    /// every phase lands in `rec` (tagged with the recorder's current
    /// step), and the phase tag is bumped on each recovery re-partition
    /// so spans remain attributable after node ids change meaning.
    /// Recording is strictly observational — `None` takes the identical
    /// code path.
    pub fn run_step_recorded<F>(&mut self, rec: Option<&Recorder>, run: F) -> Result<ExecOutcome>
    where
        F: Fn(Task) -> Result<()> + Sync,
    {
        self.last_lost.clear();
        self.last_recomputed = 0;
        let step_no = self.step_no;
        self.step_no += 1;
        let mut phase = 0u32;
        if let Some(r) = rec {
            r.set_phase(phase);
        }

        let mut include = vec![true; self.plan.graph().len()];
        // finished mask over the *base* graph, accumulated across phases
        let mut finished_base =
            vec![false; self.recovery.as_ref().map_or(0, |c| c.base.len())];
        let mut device_peaks = vec![0u64; self.plan.devices()];
        let mut retries = 0u64;
        let mut backoff_s = 0f64;

        loop {
            let faults = FaultArgs {
                injector: self.faults.injector.as_ref(),
                retry: self.faults.retry,
                step: step_no,
                recorder: rec,
            };
            let graph = self.plan.graph();
            let ran = self.exec.run_step_faulty(&self.plan, &include, faults, |id| {
                run(graph.node(id).task)
            })?;
            match ran {
                StepRun::Done(out) => {
                    for (acc, p) in device_peaks.iter_mut().zip(&out.device_peaks) {
                        *acc = (*acc).max(*p);
                    }
                    retries += out.retries;
                    backoff_s += out.modeled_backoff_s;
                    return Ok(ExecOutcome {
                        peak_bytes: device_peaks.iter().copied().max().unwrap_or(0),
                        device_peaks,
                        trace: out.trace,
                        retries,
                        modeled_backoff_s: backoff_s,
                    });
                }
                StepRun::Lost {
                    device,
                    node,
                    finished,
                    partial,
                } => {
                    for (acc, p) in device_peaks.iter_mut().zip(&partial.device_peaks) {
                        *acc = (*acc).max(*p);
                    }
                    retries += partial.retries;
                    backoff_s += partial.modeled_backoff_s;
                    self.last_lost.push(device);
                    let label = self.plan.graph().node(node).label.clone();
                    let lost = |label: &str| Error::DeviceLost {
                        device,
                        node: label.to_string(),
                    };
                    if self.faults.on_lost == DeviceLostPolicy::Fail {
                        return Err(lost(&label));
                    }
                    let Some(ctx) = self.recovery.as_mut() else {
                        return Err(lost(&label));
                    };
                    // fold this phase's finished frontier onto the base
                    // graph (transfer nodes have no base counterpart)
                    for (id, &done) in finished.iter().enumerate() {
                        if done {
                            if let Some(o) = self.plan.orig()[id] {
                                finished_base[o] = true;
                            }
                        }
                    }
                    ctx.topo.mark_failed(device);
                    if ctx.topo.alive_count() == 0 {
                        return Err(lost(&label));
                    }
                    // survivors' budgets, shaped exactly like build time
                    let budgets: Vec<u64> = ctx
                        .topo
                        .budgets(ctx.xi)
                        .into_iter()
                        .map(|cap| cap.min(ctx.mem_budget))
                        .collect();
                    let Ok(mut plan) =
                        ShardPlan::build(&ctx.base, &ctx.topo, ctx.policy, budgets)
                    else {
                        return Err(lost(&label));
                    };
                    // re-optimize at the level the lost plan was built
                    // with, so recovery never changes the optimization
                    // story mid-run
                    if ctx.opt_level > 0
                        && plan.optimize(ctx.opt_level, &ctx.topo).is_err()
                    {
                        return Err(lost(&label));
                    }
                    let needed = vec![true; ctx.base.len()];
                    let closure =
                        interp::recompute_closure(&ctx.base, &needed, &finished_base);
                    let next = closure_on_plan(&plan, &closure);
                    if plan.check_budgets_subset(&next).is_err() {
                        return Err(lost(&label));
                    }
                    self.last_recomputed +=
                        next.iter().filter(|&&b| b).count() as u64;
                    include = next;
                    self.plan = plan;
                    phase += 1;
                    if let Some(r) = rec {
                        r.set_phase(phase);
                    }
                }
            }
        }
    }

    /// Feed calibrated per-device rates back into the partitioner: apply
    /// `rates` (`CostModel::secs_per_byte` after `costmodel::calibrate`)
    /// to the recovery topology so DpBoundary/greedy price with measured
    /// reality, rebuild the plan over the survivors, and swap it in only
    /// when its modeled makespan under `model` is **no worse** than the
    /// stale plan's — a recalibration can never make the modeled schedule
    /// slower, by construction (docs/SHARDING.md, docs/OBSERVABILITY.md).
    ///
    /// Between-step plan swaps preserve bit-identity for the same reason
    /// the device-loss recovery's mid-step swaps do: placement never
    /// changes arithmetic, every f32 reduction stays inside barrier tasks
    /// running in base-node id order.
    ///
    /// Returns `None` when there is no recovery context
    /// ([`ShardState::with_plan`]) or no budget-feasible rebuild — the
    /// stale plan stays in place either way.
    pub fn recalibrate(
        &mut self,
        rates: &[f64],
        model: &crate::costmodel::CostModel,
    ) -> Option<Recalibration> {
        let ctx = self.recovery.as_mut()?;
        ctx.topo.apply_secs_per_byte(rates);
        let budgets: Vec<u64> = ctx
            .topo
            .budgets(ctx.xi)
            .into_iter()
            .map(|cap| cap.min(ctx.mem_budget))
            .collect();
        let mut plan = ShardPlan::build(&ctx.base, &ctx.topo, ctx.policy, budgets).ok()?;
        if ctx.opt_level > 0 {
            plan.optimize(ctx.opt_level, &ctx.topo).ok()?;
        }
        if plan.check_budgets().is_err() {
            return None;
        }
        let stale_s = model.makespan(self.plan.graph(), self.plan.device_of(), self.plan.devices());
        let fresh_s = model.makespan(plan.graph(), plan.device_of(), plan.devices());
        let swapped = fresh_s <= stale_s;
        if swapped {
            self.plan = plan;
        }
        Some(Recalibration {
            stale_s,
            fresh_s,
            swapped,
        })
    }
}

/// Outcome of one [`ShardState::recalibrate`] guarded plan swap: the
/// stale and freshly-rebuilt plans' modeled makespans under the same
/// calibrated model, and whether the fresh plan was adopted
/// (`fresh_s <= stale_s` — asserted by `tests/telemetry_loop.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Recalibration {
    pub stale_s: f64,
    pub fresh_s: f64,
    pub swapped: bool,
}

/// Scheduler state carried by the trainer: the active [`SchedConfig`]
/// plus the sharded execution state built for it.  Reconfiguration is
/// **transactional**: [`SchedState::set`] performs every fallible step
/// before touching a field, so a failed reconfiguration leaves the
/// previous (working) configuration fully in place — the trainer never
/// reports pipelined while stepping serially.
struct SchedState {
    cfg: SchedConfig,
    shard: Option<ShardState>,
}

impl SchedState {
    fn new() -> SchedState {
        SchedState {
            cfg: SchedConfig::default(),
            shard: None,
        }
    }

    /// Swap in `cfg`, building the sharded state for a pipelined policy.
    /// `program` is the trainer's lowered program (`None` when the plan
    /// was never lowered — a naive-infeasible manifest), `xi` the
    /// always-resident bytes.  On `Err` no field has changed.
    fn set(
        &mut self,
        program: Option<&RowProgram>,
        cfg: SchedConfig,
        xi: u64,
        opt_level: u8,
    ) -> Result<()> {
        let shard = match cfg.policy {
            Policy::Serial => None,
            Policy::Pipelined => {
                let program = program.ok_or_else(|| {
                    Error::Sched(
                        "cannot switch to pipelined execution: the step plan was never \
                         lowered (naive split infeasible for this manifest)"
                            .into(),
                    )
                })?;
                Some(ShardState::build(program, &cfg, xi, opt_level)?)
            }
        };
        self.cfg = cfg;
        self.shard = shard;
        Ok(())
    }
}

/// Telemetry carried by a recording trainer ([`Trainer::set_recording`]):
/// the span [`Recorder`] every driver writes into, the [`obs::RunReport`]
/// accumulated step by step, the [`CostModel`] used for makespan
/// predictions (replaced in place by [`Trainer::calibrate`] and the
/// online loop), every drained span — kept because calibration and the
/// Perfetto export both need the whole run — plus the online loop's
/// state: the per-(device, kind) [`obs::drift::DriftMonitor`], the
/// bounded [`obs::flight::FlightRecorder`] crash ring, and the Perfetto
/// instant marks accumulated when drift flags.
struct ObsState {
    recorder: Recorder,
    report: obs::RunReport,
    model: CostModel,
    spans: Vec<obs::Span>,
    step_no: u32,
    drift: obs::drift::DriftMonitor,
    flight: obs::flight::FlightRecorder,
    marks: Vec<obs::perfetto::InstantMark>,
}

/// Row-centric trainer over an artifact bundle.
pub struct Trainer<'r> {
    pub rt: &'r Runtime,
    pub params: ParamSet,
    pub optimizer: Optimizer,
    /// Prebuilt execution table, fixed at construction (swapping modes
    /// means a new `Trainer`).
    plan: StepPlan,
    /// Row scheduler configuration + sharded execution state
    /// ([`Policy::Serial`], no shard, by default).  The shard half is
    /// `Some` exactly when the policy is pipelined (one stock device
    /// unless `SchedConfig::shard` says otherwise) — [`SchedState::set`]
    /// keeps the pair consistent transactionally.
    sched: SchedState,
    /// Fault-injection knobs ([`Trainer::set_faults`]); re-applied to the
    /// fresh shard state on every reconfiguration.
    faults: FaultConfig,
    /// The lowered row program (`None` only for a naive-infeasible plan).
    program: Option<RowProgram>,
    /// Event trace of the most recent step (per-device lanes via
    /// `TraceEvent::device`; the serial driver synthesizes its
    /// single-worker ledger-replay trace).
    last_trace: Option<Trace>,
    /// Telemetry (`None` until [`Trainer::set_recording`]).
    obs: Option<ObsState>,
    /// Refit the cost model from accumulated spans every n steps (0 = off;
    /// [`Trainer::recalibrate_every`]).  Survives `set_sched` re-arming.
    recalibrate_every: u32,
    /// `rowir::opt` pipeline level applied to the lowered program and to
    /// every sharded plan built from it (0 = off; [`Trainer::set_opt_level`]).
    opt_level: u8,
    /// What the optimizer did to the serial program (`None` at level 0).
    /// The sharded plan's own report lives in its [`ShardState`].
    opt_report: Option<rowir::OptReport>,
}

impl<'r> Trainer<'r> {
    pub fn new(rt: &'r Runtime, mode: Mode, lr: f32, seed: u64) -> Result<Trainer<'r>> {
        Trainer::with_optimizer(rt, mode, Optimizer::sgd(lr), seed)
    }

    /// Use a stateful optimizer (momentum/Adam); its state bytes belong to
    /// ξ in the planners' accounting (`Optimizer::state_bytes`).
    ///
    /// Builds the mode's [`StepPlan`] and lowers its [`RowProgram`] here —
    /// executable resolution, row geometry and the dataflow lowering all
    /// happen once, not per step.
    pub fn with_optimizer(
        rt: &'r Runtime,
        mode: Mode,
        optimizer: Optimizer,
        seed: u64,
    ) -> Result<Trainer<'r>> {
        let params = ParamSet::init(&rt.manifest.model, seed);
        let plan = StepPlan::build(&rt.manifest, mode)?;
        let program = match &plan.kind {
            PlanKind::NaiveInfeasible(_) => None,
            _ => Some(plan.lower(&rt.manifest)?),
        };
        // warm start: compile every executable the plan references now, so
        // no step (and no step timing) ever includes a first-use compile
        for h in plan.handles() {
            rt.ensure_compiled_h(h)?;
        }
        Ok(Trainer {
            rt,
            params,
            optimizer,
            plan,
            sched: SchedState::new(),
            faults: FaultConfig::default(),
            program,
            last_trace: None,
            obs: None,
            recalibrate_every: 0,
            opt_level: 0,
            opt_report: None,
        })
    }

    /// The execution mode the step plan was built for.
    pub fn mode(&self) -> Mode {
        self.plan.mode
    }

    /// Switch between serial and pipelined/sharded row execution.
    ///
    /// For [`Policy::Pipelined`] this builds the sharded execution state
    /// once — the real `shard::Topology` from `cfg.shard`'s device specs
    /// (mixed RTX 3090 / A100 / capacity-scaled topologies are first
    /// class), the partition, the transfer lowering (identity on one
    /// device) and the **persistent** worker pool every subsequent step
    /// reuses.  Each device's admission-ledger budget is
    /// `min(cfg.mem_budget, usable HBM − ξ)` for *that* device
    /// (`Topology::budgets`), and the plan is rejected up front when
    /// any device's serial-order replay peak exceeds its clamped budget.
    ///
    /// Fallible and **transactional**: on error — including asking for a
    /// pipelined policy when the step plan could never be lowered — the
    /// trainer keeps its previous (working) configuration in full.
    pub fn set_sched(&mut self, cfg: SchedConfig) -> Result<()> {
        let xi = self.params.size_bytes() + self.optimizer.state_bytes(&self.params);
        self.sched.set(self.program.as_ref(), cfg, xi, self.opt_level)?;
        if let Some(ss) = self.sched.shard.as_mut() {
            ss.set_faults(&self.faults);
        }
        // a prior step's trace belongs to the previous plan's graph;
        // keeping it would let trace_json pair it with the new one
        self.last_trace = None;
        // likewise the recorder's lane count and the report's
        // devices/cost-model context — re-arm recording from scratch
        if self.obs.is_some() {
            self.set_recording(true);
        }
        Ok(())
    }

    /// Set the `rowir::opt` pipeline level (`--opt-level 0|1|2`, clamped
    /// to 2) and re-apply it end to end: the step plan is re-lowered to a
    /// pristine program, optimized serially when `level > 0`, and the
    /// active sched configuration is rebuilt so a sharded plan gets its
    /// own post-partition optimization pass ([`ShardPlan::optimize`]).
    ///
    /// Fallible and transactional like [`Trainer::set_sched`]: on error
    /// (e.g. the optimizer declares the budgets infeasible) the trainer
    /// keeps its previous program, level and schedule.
    pub fn set_opt_level(&mut self, level: u8) -> Result<()> {
        let level = level.min(2);
        // re-lower from scratch: optimizing an already-optimized program
        // is a no-op, but level changes must not compound on stale state
        let (program, report) = match &self.plan.kind {
            PlanKind::NaiveInfeasible(_) => (None, None),
            _ => {
                let pristine = self.plan.lower(&self.rt.manifest)?;
                if level > 0 {
                    let (p, r) = rowir::optimize(&pristine, level, &rowir::OptContext::serial())?;
                    (Some(p), Some(r))
                } else {
                    (Some(pristine), None)
                }
            }
        };
        let prev_level = self.opt_level;
        let prev_program = std::mem::replace(&mut self.program, program);
        let prev_report = std::mem::replace(&mut self.opt_report, report);
        self.opt_level = level;
        if let Err(e) = self.set_sched(self.sched.cfg.clone()) {
            self.program = prev_program;
            self.opt_report = prev_report;
            self.opt_level = prev_level;
            return Err(e);
        }
        Ok(())
    }

    /// The active optimizer level (0 = off).
    pub fn opt_level(&self) -> u8 {
        self.opt_level
    }

    /// What the optimizer did to the *active* plan: the sharded plan's
    /// post-partition report when sharding is live, else the serial
    /// program's.  `None` at level 0 or before a program is lowered.
    pub fn opt_report(&self) -> Option<&rowir::OptReport> {
        match self.sched.shard.as_ref() {
            Some(ss) => ss.opt_report().or(self.opt_report.as_ref()),
            None => self.opt_report.as_ref(),
        }
    }

    /// Install fault-injection knobs (`--fault-plan`, `--retry`,
    /// `--on-device-lost`).  Off the sharded path they are inert — the
    /// serial and plain-pipelined drivers run fault-free.
    pub fn set_faults(&mut self, cfg: FaultConfig) {
        if let Some(ss) = self.sched.shard.as_mut() {
            ss.set_faults(&cfg);
        }
        self.faults = cfg;
    }

    pub fn faults(&self) -> &FaultConfig {
        &self.faults
    }

    pub fn sched(&self) -> &SchedConfig {
        &self.sched.cfg
    }

    /// The lowered row program (for inspection/attribution).
    pub fn row_program(&self) -> Option<&RowProgram> {
        self.program.as_ref()
    }

    /// The sharded plan (partition, transfers, per-device budgets) when
    /// the policy is pipelined.
    pub fn shard_state(&self) -> Option<&ShardState> {
        self.sched.shard.as_ref()
    }

    /// Per-row event trace of the most recent pipelined step, with
    /// per-device lanes in `TraceEvent::device`.
    pub fn last_trace(&self) -> Option<&Trace> {
        self.last_trace.as_ref()
    }

    /// Attribution JSON of the most recent pipelined step (per-device
    /// lanes + `Transfer` spans) — what `--trace-out` writes.
    pub fn trace_json(&self) -> Option<String> {
        let trace = self.last_trace.as_ref()?;
        let graph = match &self.sched.shard {
            Some(ss) => ss.plan.graph(),
            None => self.program.as_ref()?.graph(),
        };
        Some(trace.to_json(graph))
    }

    /// Turn span recording + run-report accumulation on (fresh state) or
    /// off.  The recorder gets one lane per configured worker; the report
    /// and its prediction [`CostModel`] are sized from the active sched
    /// configuration, so call this *after* [`Trainer::set_sched`]
    /// (reconfiguring re-arms recording automatically, discarding the
    /// previous report).  Recording is strictly observational — results
    /// stay bit-identical to a non-recording run.
    pub fn set_recording(&mut self, on: bool) {
        if !on {
            self.obs = None;
            return;
        }
        let workers = self.sched.cfg.workers.max(1);
        let (devices, model) = match self.sched.shard.as_ref() {
            Some(ss) => {
                let model = match ss.topology() {
                    Some(topo) => CostModel::from_topology(topo),
                    None => CostModel::analytic(
                        &vec![DeviceModel::rtx3090(); ss.plan.devices()],
                        DeviceModel::rtx3090().pcie_bytes_per_sec,
                    ),
                };
                (ss.plan.devices(), model)
            }
            None => (
                1,
                CostModel::analytic(
                    &[DeviceModel::rtx3090()],
                    DeviceModel::rtx3090().pcie_bytes_per_sec,
                ),
            ),
        };
        let mode = self.plan.mode.label();
        // a crash report should say whether the plan it describes was
        // statically clean (docs/ANALYSIS.md)
        let mut flight = obs::flight::FlightRecorder::default();
        if let Some(v) = self.plan_lint_verdict() {
            flight.set_plan_lint(v);
        }
        let mut report = obs::RunReport::new(
            format!("train {mode} ({:?})", self.sched.cfg.policy),
            mode,
            workers,
            devices,
        );
        // the report describes the *optimized* plan when a level is set
        if let Some(r) = self.opt_report() {
            report.set_optimizer(obs::OptimizerSummary::from(r));
        }
        self.obs = Some(ObsState {
            recorder: Recorder::new(workers),
            report,
            model,
            spans: Vec::new(),
            step_no: 0,
            drift: obs::drift::DriftMonitor::default(),
            flight,
            marks: Vec::new(),
        });
    }

    /// Static-analysis report of the active plan: the sharded plan's
    /// full report (graph passes + shard checks) when sharding is
    /// active, else the lowered program's graph report.  `None` before a
    /// program is lowered.  What `train --lint-strict` gates on.
    pub fn plan_lint_report(&self) -> Option<crate::rowir::analysis::Report> {
        match self.sched.shard.as_ref() {
            Some(ss) => Some(ss.plan.analyze()),
            None => self
                .program
                .as_ref()
                .map(|p| crate::rowir::analysis::analyze(p.graph())),
        }
    }

    /// The active plan's one-line static-lint verdict
    /// ([`crate::rowir::analysis::Report::verdict`]).
    pub fn plan_lint_verdict(&self) -> Option<String> {
        self.plan_lint_report().map(|r| r.verdict())
    }

    /// Whether span recording is armed.
    pub fn recording(&self) -> bool {
        self.obs.is_some()
    }

    /// The run report accumulated since recording was armed.
    pub fn run_report(&self) -> Option<&obs::RunReport> {
        self.obs.as_ref().map(|o| &o.report)
    }

    /// The run report as versioned JSON (what `--report-out` writes).
    pub fn report_json(&self) -> Option<String> {
        self.obs.as_ref().map(|o| o.report.to_json())
    }

    /// Every span recorded since recording was armed (drained per step,
    /// in [`Recorder::drain`] order).
    pub fn spans(&self) -> &[obs::Span] {
        self.obs.as_ref().map_or(&[], |o| o.spans.as_slice())
    }

    /// The prediction cost model currently in use (analytic until
    /// [`Trainer::calibrate`] replaces it with the fitted one).
    pub fn cost_model(&self) -> Option<&CostModel> {
        self.obs.as_ref().map(|o| &o.model)
    }

    /// Least-squares fit of the cost model over every recorded span
    /// ([`costmodel::calibrate`]).  Installs the fitted model — later
    /// steps are predicted with it — and stores the report in the run
    /// report's `calibration` section.
    pub fn calibrate(&mut self) -> Option<costmodel::CalibrationReport> {
        let o = self.obs.as_mut()?;
        let (fitted, rep) = costmodel::calibrate(&o.spans, &o.model);
        o.model = fitted;
        o.report.set_calibration(rep.clone());
        Some(rep)
    }

    /// Arm the online feedback loop: every `n` steps, refit the cost
    /// model from the accumulated spans ([`costmodel::calibrate`]) and —
    /// if the step's drift monitor flagged — rebuild the shard plan under
    /// the calibrated per-device rates, adopting it only when its modeled
    /// makespan is no worse than the stale plan's
    /// ([`ShardState::recalibrate`]).  `0` disables the loop (the
    /// default).  Requires recording ([`Trainer::set_recording`]); the
    /// whole loop is observational on the numerics — loss and parameters
    /// stay bit-identical to a serial run.
    pub fn recalibrate_every(&mut self, n: u32) {
        self.recalibrate_every = n;
    }

    /// The flight recorder's crash report as JSON (what `--flight-out`
    /// writes): the bounded ring of recent spans + noted events + a
    /// metrics snapshot, under the given `reason`.  `None` when recording
    /// is off.
    pub fn flight_json(&self, reason: &str) -> Option<String> {
        let o = self.obs.as_ref()?;
        Some(o.flight.to_json(reason, Some(&o.recorder.metrics().snapshot())))
    }

    /// A snapshot of the lock-cheap metrics registry fed by every
    /// dispatch ([`Recorder::push`]).  `None` when recording is off.
    pub fn metrics_snapshot(&self) -> Option<obs::metrics::MetricsSnapshot> {
        self.obs.as_ref().map(|o| o.recorder.metrics().snapshot())
    }

    /// The unified Perfetto/Chrome trace of the recorded run (what
    /// `--perfetto-out` writes): execution lanes + per-device in-flight
    /// counters from the spans, retry/loss markers from the most recent
    /// step's event trace.
    pub fn perfetto_json(&self) -> Option<String> {
        let o = self.obs.as_ref()?;
        Some(obs::perfetto::chrome_trace(
            &o.report.title,
            &o.spans,
            &o.recorder.step_windows(),
            &o.marks,
            self.last_trace.as_ref(),
            None,
        ))
    }

    /// One training step on (x, y); returns the loss.
    pub fn step(&mut self, x: &Tensor, y1h: &Tensor) -> Result<StepStats> {
        let t0 = Instant::now();
        let exec0 = self.rt.stats().executions;
        let program = match (&self.plan.kind, &self.program) {
            (PlanKind::NaiveInfeasible(msg), _) => {
                return Err(Error::InfeasiblePlan(msg.clone()))
            }
            (_, Some(p)) => p,
            (_, None) => return Err(Error::Sched("step plan was never lowered".into())),
        };
        let pipelined = self.sched.cfg.policy == Policy::Pipelined;
        // makespan prediction under the step's (pre-fault) plan; the
        // single-device list schedule is the serial sum, the honest
        // reference for the serial and plain-pipelined drivers
        let predicted_s = self.obs.as_ref().map(|o| match self.sched.shard.as_ref() {
            Some(ss) if pipelined => {
                o.model
                    .makespan(ss.plan.graph(), ss.plan.device_of(), ss.plan.devices())
            }
            _ => {
                let g = program.graph();
                o.model.makespan(g, &vec![0; g.len()], 1)
            }
        });
        if let Some(o) = self.obs.as_ref() {
            o.recorder.begin_step(o.step_no);
        }
        let rec = self.obs.as_ref().map(|o| &o.recorder);
        let dispatched = if pipelined {
            self.plan
                .step_pipelined_recorded(
                    self.rt,
                    program,
                    &self.params,
                    &self.sched.cfg,
                    self.sched.shard.as_mut(),
                    x,
                    y1h,
                    rec,
                )
                .map(|(loss, grads, outcome)| {
                    let peak = outcome.peak_bytes;
                    let device_peaks = outcome.device_peaks.clone();
                    let (retries, backoff_s) = (outcome.retries, outcome.modeled_backoff_s);
                    self.last_trace = Some(outcome.trace);
                    (loss, grads, peak, device_peaks, retries, backoff_s)
                })
        } else {
            self.plan
                .step_serial_recorded(self.rt, program, &self.params, x, y1h, rec)
                .map(|(loss, grads, outcome)| {
                    let peak = outcome.peak_bytes;
                    // the serial driver emits no pool events; synthesize
                    // the single-worker trace replaying the interpreter's
                    // ledger so `--trace-out` works (and `check_complete`
                    // holds) in serial mode too
                    self.last_trace = Some(Trace::serial(program.graph()));
                    (loss, grads, peak, vec![peak], 0, 0.0)
                })
        };
        let (loss, grads, peak_bytes, device_peaks, retries, backoff_s) = match dispatched {
            Ok(v) => v,
            Err(e) => {
                // a failed step is exactly what the flight recorder
                // exists for: capture the partial dispatch record (the
                // failing dispatch included — injected faults record
                // zero-duration spans) before propagating
                if let Some(o) = self.obs.as_mut() {
                    o.recorder.end_step();
                    let spans = o.recorder.drain();
                    o.flight.push_spans(&spans);
                    o.flight.note(format!("step {}: {e}", o.step_no));
                    o.spans.extend(spans);
                }
                return Err(e);
            }
        };
        let (lost_devices, recomputed_nodes) = match &self.sched.shard {
            Some(ss) if pipelined => (ss.last_lost().to_vec(), ss.last_recomputed()),
            _ => (Vec::new(), 0),
        };
        self.optimizer.step(&mut self.params, &grads)?;
        let mut stats = StepStats {
            loss,
            peak_bytes,
            device_peaks,
            step_ms: t0.elapsed().as_secs_f64() * 1e3,
            executions: self.rt.stats().executions - exec0,
            retries,
            modeled_backoff_s: backoff_s,
            lost_devices,
            recomputed_nodes,
            drift_max: 0.0,
            drifting: 0,
            stragglers: Vec::new(),
            recalibrated: false,
            repartitioned: false,
        };
        if let Some(o) = self.obs.as_mut() {
            o.recorder.end_step();
            let spans = o.recorder.drain();
            // drift is judged against the model that made this step's
            // predictions — the pre-recalibration one
            let drift = o.drift.observe(&spans, &o.model);
            o.flight.push_spans(&spans);
            if !stats.lost_devices.is_empty() {
                // recovery swapped in a repartitioned plan mid-step: the
                // crash report's verdict must describe the *active* plan
                if let Some(ss) = self.sched.shard.as_ref() {
                    o.flight.set_plan_lint(ss.plan.analyze().verdict());
                }
            }
            if !drift.stragglers.is_empty() {
                o.flight.note(format!(
                    "step {}: straggler device(s) {:?}",
                    o.step_no, drift.stragglers
                ));
            }
            if drift.flagged() {
                let ts_ns = spans.iter().map(|s| s.end_ns()).max().unwrap_or(0);
                o.marks.push(obs::perfetto::InstantMark {
                    ts_ns,
                    label: format!(
                        "drift step {}: {} cell(s), {} straggler(s)",
                        o.step_no,
                        drift.drifting.len(),
                        drift.stragglers.len()
                    ),
                });
            }
            let input = obs::StepInput {
                step: o.step_no,
                loss: stats.loss as f64,
                peak_bytes: stats.peak_bytes,
                device_peaks: stats.device_peaks.clone(),
                step_ms: stats.step_ms,
                executions: stats.executions,
                retries: stats.retries,
                modeled_backoff_s: stats.modeled_backoff_s,
                lost_devices: stats.lost_devices.len() as u64,
                recomputed_nodes: stats.recomputed_nodes,
                drift_max: drift.max_abs_ewma,
                drifting: drift.drifting.len() as u64,
                stragglers: drift.stragglers.iter().map(|&d| d as u64).collect(),
            };
            o.report
                .push_step(&input, &spans, &o.model, predicted_s.unwrap_or(0.0));
            o.spans.extend(spans);
            o.step_no += 1;
            stats.drift_max = drift.max_abs_ewma;
            stats.drifting = drift.drifting.len() as u64;
            stats.stragglers = drift.stragglers.clone();
            // the feedback edge: refit the model from everything recorded
            // so far, and — only when drift actually flagged — rebuild the
            // shard plan under the calibrated rates, adopting it only if
            // its modeled makespan is no worse than the stale plan's
            if self.recalibrate_every > 0 && o.step_no % self.recalibrate_every == 0 {
                let (fitted, rep) = costmodel::calibrate(&o.spans, &o.model);
                o.model = fitted;
                o.report.set_calibration(rep);
                stats.recalibrated = true;
                let mut repartitioned = false;
                if drift.flagged() {
                    if let Some(ss) = self.sched.shard.as_mut() {
                        if let Some(out) = ss.recalibrate(&o.model.secs_per_byte, &o.model) {
                            debug_assert!(
                                !out.swapped || out.fresh_s <= out.stale_s,
                                "a repartition must never worsen the modeled makespan"
                            );
                            if out.swapped {
                                repartitioned = true;
                                // the old trace pairs with the old plan's
                                // graph; keeping it would let trace_json
                                // mix the two
                                self.last_trace = None;
                                o.flight.set_plan_lint(ss.plan.analyze().verdict());
                                o.flight.note(format!(
                                    "step {}: repartitioned (makespan {:.3e}s -> {:.3e}s)",
                                    o.step_no - 1,
                                    out.stale_s,
                                    out.fresh_s
                                ));
                            }
                        }
                    }
                }
                o.report.record_recalibration(repartitioned);
                stats.repartitioned = repartitioned;
            }
        }
        Ok(stats)
    }

    /// Forward-only pass producing z^L (used by tests + quickstart).
    /// Row-centric modes interpret the program's FP prefix
    /// ([`StepPlan::forward_zl`]); Base runs its fused forward executable.
    pub fn forward(&mut self, x: &Tensor) -> Result<Tensor> {
        match &self.plan.kind {
            PlanKind::Base(bp) => {
                let mut args: Vec<TensorView> = Vec::with_capacity(1 + bp.n_conv);
                args.push(x.view());
                args.extend(self.params.tensors[..bp.n_conv].iter().map(|t| t.view()));
                Ok(self.rt.execute_h(bp.fwd, &args)?.remove(0))
            }
            PlanKind::NaiveInfeasible(msg) => Err(Error::InfeasiblePlan(msg.clone())),
            _ => {
                let program = self
                    .program
                    .as_ref()
                    .ok_or_else(|| Error::Sched("step plan was never lowered".into()))?;
                self.plan.forward_zl(self.rt, program, &self.params, x)
            }
        }
    }
}

// ---------------- task handlers ----------------
//
// One set of handlers serves every driver: the serial interpreter calls
// them from the caller's thread in id order; the worker pools call them
// from scheduler threads.  Free functions sharing nothing but `&`
// references (ExecBackend is `Sync`, slots are mutex cells).  Determinism
// contract: per-row handlers write slot `r` only; all float reductions
// live in the barrier handlers and iterate rows in the interpreter's
// (reversed) order.

/// Handoff cells for one step, matching the plan kind.
enum Cells {
    Base(Slot<(f32, Vec<Tensor>)>),
    Hybrid(HybridCells),
    Naive(NaiveCells),
}

/// Take the finished step's (loss, gradients) out of the terminal slot.
fn take_result(cells: &Cells) -> Result<(f32, Vec<Tensor>)> {
    match cells {
        Cells::Base(out) => out.take("base.out"),
        Cells::Hybrid(c) => c.out.take("out"),
        Cells::Naive(c) => c.out.take("out"),
    }
}

/// Dispatch one node's task against the prebuilt plan table — the single
/// node-execution entry point every driver funnels through.
fn run_task(
    ex: &dyn ExecBackend,
    kind: &PlanKind,
    params: &ParamSet,
    x: &Tensor,
    y1h: &Tensor,
    cells: &Cells,
    task: Task,
) -> Result<()> {
    match (kind, cells) {
        (PlanKind::Base(bp), Cells::Base(out)) => match task {
            Task::BaseStep => pipe_base(ex, params, bp, x, y1h, out),
            t => Err(Error::Sched(format!("task {t:?} in base step"))),
        },
        (PlanKind::Hybrid(hp), Cells::Hybrid(c)) => {
            run_hybrid_task(ex, params, hp, x, y1h, c, task)
        }
        (PlanKind::Naive(np), Cells::Naive(c)) => {
            run_naive_task(ex, params, np, x, y1h, c, task)
        }
        _ => Err(Error::Sched("step cells do not match the plan kind".into())),
    }
}

/// Handoff cells for one hybrid/2PS step.
struct HybridCells {
    za: Vec<Slot<Tensor>>,
    /// checkpoint, read by FP-B and BP-B rows concurrently
    zck: Slot<Arc<Tensor>>,
    zb: Vec<Slot<Tensor>>,
    tps_z: Vec<Slot<Tensor>>,
    tps_cache: Vec<Slot<Vec<Tensor>>>,
    zl: Slot<Tensor>,
    loss: Slot<f32>,
    dzl: Slot<Arc<Tensor>>,
    head_grads: Slot<(Tensor, Tensor)>,
    bp_b: Vec<Slot<(Vec<Tensor>, Tensor)>>,
    grads_mid: Slot<Vec<Tensor>>,
    dzck: Slot<Arc<Tensor>>,
    bp_a: Vec<Slot<Vec<Tensor>>>,
    out: Slot<(f32, Vec<Tensor>)>,
}

impl HybridCells {
    fn new(hp: &HybridPlan) -> Self {
        let (n_b, n_tps) = match &hp.tps {
            Some(tp) => (0, tp.rows.len()),
            None => (hp.segs[1].rows.len(), 0),
        };
        HybridCells {
            za: Slot::many(hp.segs[0].rows.len()),
            zck: Slot::new(),
            zb: Slot::many(n_b),
            tps_z: Slot::many(n_tps),
            tps_cache: Slot::many(n_tps),
            zl: Slot::new(),
            loss: Slot::new(),
            dzl: Slot::new(),
            head_grads: Slot::new(),
            bp_b: Slot::many(hp.segs[1].rows.len()),
            grads_mid: Slot::new(),
            dzck: Slot::new(),
            bp_a: Slot::many(hp.segs[0].rows.len()),
            out: Slot::new(),
        }
    }
}

fn run_hybrid_task(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    hp: &HybridPlan,
    x: &Tensor,
    y1h: &Tensor,
    cells: &HybridCells,
    task: Task,
) -> Result<()> {
    match task {
        Task::FpRow { seg: 0, row } => {
            pipe_seg_fp_row(ex, params, &hp.segs[0], row, x, &cells.za[row])
        }
        Task::FpRow { seg: _, row } => {
            let zck = cells.zck.cloned("zck")?;
            pipe_seg_fp_row(ex, params, &hp.segs[1], row, &zck, &cells.zb[row])
        }
        Task::TpsRow { row } => pipe_tps_row(ex, params, hp, row, x, cells),
        Task::CkBarrier => {
            let zck = pipe_concat(&cells.za, "fp.za")?;
            cells.zck.put("zck", Arc::new(zck))
        }
        Task::ZlBarrier => {
            let zl = match &hp.tps {
                Some(_) => pipe_concat(&cells.tps_z, "tps.z")?,
                None => pipe_concat(&cells.zb, "fp.zb")?,
            };
            cells.zl.put("zl", zl)
        }
        Task::Head => pipe_head(
            ex,
            params,
            hp.head,
            hp.n_conv,
            y1h,
            &cells.zl,
            &cells.loss,
            &cells.dzl,
            &cells.head_grads,
        ),
        Task::BpRowB { row } => pipe_bp_row_b(ex, params, &hp.segs[1], row, cells),
        Task::ReduceB => pipe_reduce_b(params, hp, cells),
        Task::BpRowA { row } => pipe_bp_row_a(ex, params, &hp.segs[0], row, x, cells),
        Task::ReduceA => pipe_reduce_a(&hp.segs[0], cells),
        t => Err(Error::Sched(format!("task {t:?} in hybrid step"))),
    }
}

fn pipe_base(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    bp: &BasePlan,
    x: &Tensor,
    y1h: &Tensor,
    out: &Slot<(f32, Vec<Tensor>)>,
) -> Result<()> {
    let mut args: Vec<TensorView> = Vec::with_capacity(2 + params.tensors.len());
    args.push(x.view());
    args.push(y1h.view());
    args.extend(params.tensors.iter().map(|t| t.view()));
    let mut res = ex.exec(bp.step, &args)?;
    let grads = res.split_off(1);
    let loss = res[0].data[0];
    out.put("base.out", (loss, grads))
}

/// FP of one segment row (segment A from x, segment B from the checkpoint).
fn pipe_seg_fp_row(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    seg: &SegPlan,
    row: usize,
    input: &Tensor,
    out: &Slot<Tensor>,
) -> Result<()> {
    let rp = &seg.rows[row];
    let seg_params = &params.tensors[seg.param_lo..seg.param_hi];
    // zero-copy: a strided view, gathered only at the literal boundary
    let slab = input.slice_h(rp.in_iv[0], rp.in_iv[1])?;
    let mut args: Vec<TensorView> = Vec::with_capacity(1 + seg_params.len());
    args.push(slab);
    args.extend(seg_params.iter().map(|t| t.view()));
    let z = ex.exec(rp.fwd, &args)?.remove(0);
    out.put("fp.z", z)
}

/// One 2PS row: consume row r−1's boundary caches, produce z + own caches
/// (the cache handoff of §IV-A, realized as a slot chain).
fn pipe_tps_row(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    hp: &HybridPlan,
    row: usize,
    x: &Tensor,
    cells: &HybridCells,
) -> Result<()> {
    let tp = hp
        .tps
        .as_ref()
        .ok_or_else(|| Error::Sched("tps task in non-2PS plan".into()))?;
    let rp = &tp.rows[row];
    let conv = &params.tensors[..hp.n_conv];
    let own = x.slice_h(rp.own_iv[0], rp.own_iv[1])?;
    let caches: Vec<Tensor> = if row > 0 {
        cells.tps_cache[row - 1].take("tps.cache")?
    } else {
        Vec::new()
    };
    let mut out = {
        let mut args: Vec<TensorView> = Vec::with_capacity(1 + caches.len() + conv.len());
        args.push(own);
        args.extend(caches.iter().map(|t| t.view()));
        args.extend(conv.iter().map(|t| t.view()));
        ex.exec(rp.fwd, &args)?
    };
    if out.is_empty() {
        return Err(Error::Artifact("tps row returned no outputs".into()));
    }
    let z = out.remove(0);
    cells.tps_z[row].put("tps.z", z)?;
    cells.tps_cache[row].put("tps.cache", out)
}

/// Concat barrier: take every row output in row order (deterministic).
fn pipe_concat(rows: &[Slot<Tensor>], label: &str) -> Result<Tensor> {
    let owned: Vec<Tensor> = rows.iter().map(|s| s.take(label)).collect::<Result<_>>()?;
    let views: Vec<TensorView> = owned.iter().map(|t| t.view()).collect();
    Tensor::concat_h(&views)
}

/// FP→BP boundary: the FC head, shared by hybrid and naive plans.
#[allow(clippy::too_many_arguments)]
fn pipe_head(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    head: ExecHandle,
    n_conv: usize,
    y1h: &Tensor,
    zl: &Slot<Tensor>,
    loss: &Slot<f32>,
    dzl: &Slot<Arc<Tensor>>,
    head_grads: &Slot<(Tensor, Tensor)>,
) -> Result<()> {
    let z_l = zl.take("zl")?;
    let mut out = ex.exec(
        head,
        &[
            z_l.view(),
            y1h.view(),
            params.tensors[n_conv].view(),
            params.tensors[n_conv + 1].view(),
        ],
    )?;
    if out.len() != 4 {
        return Err(Error::Artifact(format!(
            "head returned {} outputs, want [loss, dzL, dWfc, dbfc]",
            out.len()
        )));
    }
    let dbfc = out.pop().expect("len checked");
    let dwfc = out.pop().expect("len checked");
    let dz_l = out.pop().expect("len checked");
    let loss_v = out.pop().expect("len checked").data[0];
    loss.put("loss", loss_v)?;
    dzl.put("dzl", Arc::new(dz_l))?;
    head_grads.put("head_grads", (dwfc, dbfc))
}

/// BP of one segment-B row: slab from the checkpoint, δ from the head.
fn pipe_bp_row_b(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    seg_b: &SegPlan,
    row: usize,
    cells: &HybridCells,
) -> Result<()> {
    let rp = &seg_b.rows[row];
    let zck = cells.zck.cloned("zck")?;
    let dzl = cells.dzl.cloned("dzl")?;
    let seg_params = &params.tensors[seg_b.param_lo..seg_b.param_hi];
    let slab = zck.slice_h(rp.in_iv[0], rp.in_iv[1])?;
    let dz = dzl.slice_h(rp.out_iv[0], rp.out_iv[1])?;
    let mut out = {
        let mut args: Vec<TensorView> = Vec::with_capacity(2 + seg_params.len());
        args.push(slab);
        args.extend(seg_params.iter().map(|t| t.view()));
        args.push(dz);
        ex.exec(rp.bwd, &args)?
    };
    let _z = out
        .pop()
        .ok_or_else(|| Error::Artifact("segB bwd returned no outputs".into()))?;
    let dx = out
        .pop()
        .ok_or_else(|| Error::Artifact("segB bwd missing dx output".into()))?;
    cells.bp_b[row].put("bp_b", (out, dx))
}

/// Reduce barrier after BP-B: fold row gradients and δ-accumulate dz_ck in
/// the interpreter's reversed row order — this fixed f32 fold order is
/// what keeps every driver's loss/params bit-identical.
fn pipe_reduce_b(params: &ParamSet, hp: &HybridPlan, cells: &HybridCells) -> Result<()> {
    let seg_b = &hp.segs[1];
    let mut grads = params.grad_zeros();
    let (dwfc, dbfc) = cells.head_grads.take("head_grads")?;
    grads[hp.n_conv] = dwfc;
    grads[hp.n_conv + 1] = dbfc;
    let zck = cells.zck.cloned("zck")?;
    let mut dz_ck = Tensor::zeros(&zck.shape);
    for (r, rp) in seg_b.rows.iter().enumerate().rev() {
        let (row_grads, dx) = cells.bp_b[r].take("bp_b")?;
        for (i, g) in row_grads.into_iter().enumerate() {
            grads[seg_b.param_lo + i].axpy(1.0, &g)?;
        }
        // overlapping slab input-gradients accumulate by linearity
        dz_ck.add_h(rp.in_iv[0], &dx)?;
    }
    cells.grads_mid.put("grads_mid", grads)?;
    cells.dzck.put("dzck", Arc::new(dz_ck))
}

/// BP of one segment-A row: slab from x, δ from the dz_ck accumulator.
fn pipe_bp_row_a(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    seg_a: &SegPlan,
    row: usize,
    x: &Tensor,
    cells: &HybridCells,
) -> Result<()> {
    let rp = &seg_a.rows[row];
    let dzck = cells.dzck.cloned("dzck")?;
    let seg_params = &params.tensors[seg_a.param_lo..seg_a.param_hi];
    let slab = x.slice_h(rp.in_iv[0], rp.in_iv[1])?;
    let dz = dzck.slice_h(rp.out_iv[0], rp.out_iv[1])?;
    let mut out = {
        let mut args: Vec<TensorView> = Vec::with_capacity(2 + seg_params.len());
        args.push(slab);
        args.extend(seg_params.iter().map(|t| t.view()));
        args.push(dz);
        ex.exec(rp.bwd, &args)?
    };
    out.pop()
        .ok_or_else(|| Error::Artifact("segA bwd returned no outputs".into()))?;
    cells.bp_a[row].put("bp_a", out)
}

/// Final reduce: fold segment A's row gradients (reversed order) and emit
/// the step result.
fn pipe_reduce_a(seg_a: &SegPlan, cells: &HybridCells) -> Result<()> {
    let mut grads = cells.grads_mid.take("grads_mid")?;
    for r in (0..seg_a.rows.len()).rev() {
        let row_grads = cells.bp_a[r].take("bp_a")?;
        for (i, g) in row_grads.into_iter().enumerate() {
            grads[seg_a.param_lo + i].axpy(1.0, &g)?;
        }
    }
    let loss = cells.loss.take("loss")?;
    cells.out.put("out", (loss, grads))
}

/// Handoff cells for one naive step.
struct NaiveCells {
    z: Vec<Slot<Tensor>>,
    zl: Slot<Tensor>,
    loss: Slot<f32>,
    dzl: Slot<Arc<Tensor>>,
    head_grads: Slot<(Tensor, Tensor)>,
    bp: Vec<Slot<Vec<Tensor>>>,
    out: Slot<(f32, Vec<Tensor>)>,
}

impl NaiveCells {
    fn new(np: &NaivePlan) -> Self {
        NaiveCells {
            z: Slot::many(np.rows.len()),
            zl: Slot::new(),
            loss: Slot::new(),
            dzl: Slot::new(),
            head_grads: Slot::new(),
            bp: Slot::many(np.rows.len()),
            out: Slot::new(),
        }
    }
}

fn run_naive_task(
    ex: &dyn ExecBackend,
    params: &ParamSet,
    np: &NaivePlan,
    x: &Tensor,
    y1h: &Tensor,
    cells: &NaiveCells,
    task: Task,
) -> Result<()> {
    let conv = &params.tensors[..np.n_conv];
    match task {
        Task::NaiveFp { row } => {
            let rp = &np.rows[row];
            let slab = x.slice_h(rp.x_iv[0], rp.x_iv[1])?;
            let mut args: Vec<TensorView> = Vec::with_capacity(1 + conv.len());
            args.push(slab);
            args.extend(conv.iter().map(|t| t.view()));
            let z = ex.exec(rp.fwd, &args)?.remove(0);
            cells.z[row].put("naive.z", z)
        }
        Task::NaiveZl => {
            let zl = pipe_concat(&cells.z, "naive.z")?;
            cells.zl.put("naive.zl", zl)
        }
        Task::NaiveHead => pipe_head(
            ex,
            params,
            np.head,
            np.n_conv,
            y1h,
            &cells.zl,
            &cells.loss,
            &cells.dzl,
            &cells.head_grads,
        ),
        Task::NaiveBp { row } => {
            let rp = &np.rows[row];
            let dzl = cells.dzl.cloned("dzl")?;
            let slab = x.slice_h(rp.x_iv[0], rp.x_iv[1])?;
            let dz = dzl.slice_h(rp.z_iv[0], rp.z_iv[1])?;
            let mut out = {
                let mut args: Vec<TensorView> = Vec::with_capacity(2 + conv.len());
                args.push(slab);
                args.extend(conv.iter().map(|t| t.view()));
                args.push(dz);
                ex.exec(rp.bwd, &args)?
            };
            out.pop()
                .ok_or_else(|| Error::Artifact("naive bwd returned no outputs".into()))?;
            cells.bp[row].put("naive.bp", out)
        }
        Task::NaiveReduce => {
            let mut grads = params.grad_zeros();
            let (dwfc, dbfc) = cells.head_grads.take("head_grads")?;
            grads[np.n_conv] = dwfc;
            grads[np.n_conv + 1] = dbfc;
            for r in (0..np.rows.len()).rev() {
                let row_grads = cells.bp[r].take("naive.bp")?;
                for (i, g) in row_grads.into_iter().enumerate() {
                    grads[i].axpy(1.0, &g)?;
                }
            }
            let loss = cells.loss.take("loss")?;
            cells.out.put("out", (loss, grads))
        }
        t => Err(Error::Sched(format!("task {t:?} in naive step"))),
    }
}

/// Convenience: train `steps` steps on the synthetic corpus; returns the
/// per-step losses.
pub fn train_loop(
    trainer: &mut Trainer<'_>,
    corpus: &SyntheticCorpus,
    steps: u64,
    log_every: u64,
) -> Result<Vec<f32>> {
    let b = trainer.rt.manifest.model.batch;
    let mut losses = Vec::with_capacity(steps as usize);
    for s in 0..steps {
        let (x, y, _) = corpus.batch(s, b);
        let stats = trainer.step(&x, &y)?;
        if log_every > 0 && s % log_every == 0 {
            println!(
                "  [{}] step {s:4}  loss {:.4}  peak {:>9}  {:.1} ms  {} execs",
                trainer.mode().label(),
                stats.loss,
                crate::metrics::fmt_bytes(stats.peak_bytes),
                stats.step_ms,
                stats.executions
            );
        }
        if !stats.loss.is_finite() {
            return Err(Error::Runtime(format!(
                "loss diverged to {} at step {s}",
                stats.loss
            )));
        }
        losses.push(stats.loss);
    }
    Ok(losses)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::DeviceModel;
    use crate::shard::{DevicePreset, DeviceSpec, ShardConfig};

    #[test]
    fn step_plan_resolves_everything_up_front() {
        let man = Manifest::demo(2);
        for mode in Mode::ALL {
            let plan = StepPlan::build(&man, mode).unwrap();
            assert_eq!(plan.mode(), mode);
            match (&plan.kind, mode) {
                (PlanKind::Base(bp), Mode::Base) => {
                    assert_eq!(bp.step.index(), man.index_of("base_step").unwrap());
                    assert_eq!(bp.fwd.index(), man.index_of("base_fwd").unwrap());
                    assert_eq!(bp.n_conv, 2);
                }
                (PlanKind::Hybrid(hp), Mode::RowHybrid) => {
                    assert!(hp.tps.is_none());
                    assert_eq!(hp.segs.len(), 2);
                    assert_eq!(hp.segs[0].rows.len(), 2);
                    let rp = &hp.segs[1].rows[1];
                    assert_eq!(rp.fwd.index(), man.index_of("segB_row1_fwd").unwrap());
                    assert_eq!(rp.bwd.index(), man.index_of("segB_row1_bwd").unwrap());
                    assert_eq!(rp.in_iv, [3, 8]);
                    assert_eq!(rp.out_iv, [4, 8]);
                }
                (PlanKind::Hybrid(hp), Mode::Tps) => {
                    let tp = hp.tps.as_ref().expect("2PS plan");
                    assert_eq!(tp.rows.len(), 2);
                    assert_eq!(tp.rows[1].own_iv, [4, 8]);
                }
                (PlanKind::Naive(np), Mode::Naive) => {
                    assert_eq!(np.rows.len(), 2);
                    assert_eq!(np.rows[0].x_iv, [0, 4]);
                    assert_eq!(np.rows[1].x_iv, [4, 8]);
                    assert_eq!(np.rows[1].z_iv, [4, 8]);
                }
                (kind, mode) => panic!("unexpected plan {kind:?} for {mode:?}"),
            }
        }
    }

    #[test]
    fn step_plan_flags_uneven_naive_split() {
        // h=8, naive_rows=3: 8 % 3 != 0 — the seed truncated, we flag
        let man = Manifest::demo(3);
        let plan = StepPlan::build(&man, Mode::Naive).unwrap();
        match &plan.kind {
            PlanKind::NaiveInfeasible(msg) => assert!(msg.contains("remainder"), "{msg}"),
            other => panic!("expected NaiveInfeasible, got {other:?}"),
        }
        // lowering an infeasible plan is a typed error, not a panic
        match plan.lower(&man) {
            Err(Error::InfeasiblePlan(msg)) => assert!(msg.contains("remainder"), "{msg}"),
            other => panic!("expected InfeasiblePlan, got {:?}", other.is_ok()),
        }
        // the other modes are unaffected by the naive split
        assert!(StepPlan::build(&man, Mode::RowHybrid).is_ok());
    }

    #[test]
    fn step_plan_errors_on_missing_executable() {
        let mut man = Manifest::demo(2);
        man.executables.retain(|e| e.name != "segB_row1_bwd");
        match StepPlan::build(&man, Mode::RowHybrid) {
            Err(Error::Artifact(msg)) => assert!(msg.contains("segB_row1_bwd"), "{msg}"),
            other => panic!("expected Artifact error, got {:?}", other.is_ok()),
        }
    }

    /// Regression (PR 4 satellite): `set_sched(Pipelined)` used to
    /// install the new config even when the step plan was never lowered,
    /// leaving `shard == None` — the trainer reported pipelined while
    /// stepping serially.  Reconfiguration is now transactional: a typed
    /// error and the previous (working) configuration fully preserved.
    #[test]
    fn sched_reconfiguration_is_transactional() {
        let man = Manifest::demo(2);
        let plan = StepPlan::build(&man, Mode::RowHybrid).unwrap();
        let program = plan.lower(&man).unwrap();

        let mut st = SchedState::new();
        let good = SchedConfig::pipelined(2);
        st.set(Some(&program), good.clone(), 0, 0).unwrap();
        assert!(st.shard.is_some(), "pipelined builds the sharded state");

        // (a) pipelined with no lowered program: Error::Sched, nothing moves
        match st.set(None, SchedConfig::pipelined(4), 0, 0) {
            Err(Error::Sched(msg)) => assert!(msg.contains("never"), "{msg}"),
            other => panic!("expected Error::Sched, got ok={:?}", other.is_ok()),
        }
        assert_eq!(st.cfg, good, "failed set must preserve the config");
        assert!(st.shard.is_some(), "…and the working sharded state");
        assert_eq!(st.shard.as_ref().unwrap().plan().devices(), 1);

        // (b) a deliberately tiny device: its clamped budget is below the
        // serial replay peak — would OOM on real hardware, so the
        // reconfiguration is rejected and the old config survives
        let tiny = SchedConfig::pipelined(2).with_shard(ShardConfig::heterogeneous(vec![
            DeviceSpec::new(DevicePreset::Rtx3090).with_hbm(64),
        ]));
        match st.set(Some(&program), tiny, 0, 0) {
            Err(Error::InfeasiblePlan(msg)) => {
                assert!(msg.contains("exceeds"), "{msg}")
            }
            other => panic!("expected InfeasiblePlan, got ok={:?}", other.is_ok()),
        }
        assert_eq!(st.cfg, good);
        assert!(st.shard.is_some());

        // (c) falling back to serial always succeeds and drops the pool
        st.set(None, SchedConfig::default(), 0, 0).unwrap();
        assert!(st.shard.is_none());
    }

    /// Regression (PR 4 satellite): per-device admission budgets used to
    /// be `vec![cfg.mem_budget; devices]`, ignoring each device's actual
    /// memory.  They now derive from `Topology::budgets(ξ)` clamped by
    /// the configured budget — a small device's ledger can never exceed
    /// its usable HBM minus the always-resident bytes.
    #[test]
    fn per_device_budgets_clamp_to_device_memory() {
        let man = Manifest::demo(2);
        let plan = StepPlan::build(&man, Mode::RowHybrid).unwrap();
        let program = plan.lower(&man).unwrap();

        // mixed topology: stock rtx3090 + a 1 MiB-scaled a100
        let small = 1u64 << 20;
        let cfg = SchedConfig::pipelined(2).with_shard(ShardConfig::heterogeneous(vec![
            DeviceSpec::new(DevicePreset::Rtx3090),
            DeviceSpec::new(DevicePreset::A100).with_hbm(small),
        ]));
        let xi = 1u64 << 10;
        let ss = ShardState::build(&program, &cfg, xi, 0).unwrap();
        let budgets = ss.plan().budgets();
        assert_eq!(
            budgets[0],
            DeviceModel::rtx3090().usable_hbm() - xi,
            "an unbounded mem_budget clamps to the device"
        );
        assert_eq!(budgets[1], (small - small / 16) - xi);

        // an explicit budget below both devices wins everywhere
        let cfg = SchedConfig {
            mem_budget: 4096,
            ..cfg
        };
        let ss = ShardState::build(&program, &cfg, xi, 0).unwrap();
        assert!(ss.plan().budgets().iter().all(|&b| b == 4096));
    }

    fn fan_plan() -> (Graph, ShardPlan) {
        use crate::memory::DeviceModel;
        use crate::rowir::NodeKind;
        use crate::shard::LinkKind;
        let mut base = Graph::new();
        let a = base.push_out(NodeKind::Row, "a", vec![], 100, 40);
        let b = base.push_out(NodeKind::Row, "b", vec![], 100, 40);
        base.push(NodeKind::Barrier, "red", vec![a, b], 80);
        let topo = Topology::uniform(2, DeviceModel::rtx3090(), LinkKind::Pcie);
        let plan =
            ShardPlan::lower(&base, &topo, &[0, 1, 0], vec![u64::MAX; 2]).unwrap();
        (base, plan)
    }

    /// Recovery's include-mask mapping: a real node reruns iff its base
    /// node is in the recompute closure; a transfer reruns iff any of
    /// its consumers does.
    #[test]
    fn closure_maps_onto_the_sharded_plan_with_its_transfers() {
        let (base, plan) = fan_plan();
        let g = plan.graph();
        let xfer = plan.transfers()[0].node;
        let needed = vec![true; base.len()];

        // b finished before the loss: a, red and b's transfer rerun
        let mut finished = vec![false; base.len()];
        finished[base.find("b").unwrap()] = true;
        let closure = interp::recompute_closure(&base, &needed, &finished);
        let inc = closure_on_plan(&plan, &closure);
        assert!(inc[g.find("a").unwrap()]);
        assert!(!inc[g.find("b").unwrap()]);
        assert!(inc[g.find("red").unwrap()]);
        assert!(inc[xfer], "transfer reruns for its included consumer");

        // both producers finished: only red (and the re-copy) remain
        finished[base.find("a").unwrap()] = true;
        let closure = interp::recompute_closure(&base, &needed, &finished);
        let inc = closure_on_plan(&plan, &closure);
        assert_eq!(
            inc.iter().filter(|&&x| x).count(),
            2,
            "red + its transfer: {inc:?}"
        );
        assert!(inc[g.find("red").unwrap()] && inc[xfer]);
    }

    /// `ShardState::run_step` plumbing that needs no backend: transient
    /// retry accounting on the success path, and a device loss without a
    /// recovery context surfacing a structured [`Error::DeviceLost`]
    /// even under the `Degrade` policy.
    #[test]
    fn shard_state_retries_and_surfaces_unrecoverable_loss() {
        use crate::faults::FaultPlan;

        let (_, plan) = fan_plan();
        let mut ss = ShardState::with_plan(plan.clone(), 1);
        ss.set_faults(&FaultConfig {
            plan: Some(FaultPlan::parse("s0.nred=transient").unwrap()),
            retry: RetryPolicy::new(3),
            on_device_lost: DeviceLostPolicy::Degrade,
        });
        let out = ss.run_step(|_| Ok(())).unwrap();
        assert_eq!(out.retries, 1, "one transient absorbed");
        assert!(out.modeled_backoff_s > 0.0);
        assert!(ss.last_lost().is_empty());
        assert_eq!(ss.last_recomputed(), 0);

        let mut ss = ShardState::with_plan(plan, 1);
        ss.set_faults(&FaultConfig {
            plan: Some(FaultPlan::parse("s0.d1=lost").unwrap()),
            retry: RetryPolicy::default(),
            on_device_lost: DeviceLostPolicy::Degrade,
        });
        match ss.run_step(|_| Ok(())) {
            Err(Error::DeviceLost { device, node }) => {
                assert_eq!(device, 1);
                assert_eq!(node, "b", "the node whose dispatch the loss hit");
            }
            other => panic!("expected DeviceLost, got ok={:?}", other.is_ok()),
        }
        assert_eq!(ss.last_lost(), &[1]);
    }
}
