//! Parameter set: He-initialized tensors + SGD update.
//!
//! Parameters never leave the coordinator (ξ in the paper's accounting);
//! gradients are accumulated across rows here — the linearity that makes
//! row-partitioned BP exact (DESIGN.md §5).

use crate::error::{Error, Result};
use crate::runtime::manifest::ModelInfo;
use crate::runtime::Tensor;
use crate::util::rng::XorShift;

/// All trainable parameters, conv layers first then the FC head, matching
/// the manifest's `param_shapes` order: [W1, b1, ..., Wfc, bfc].
#[derive(Debug, Clone)]
pub struct ParamSet {
    pub tensors: Vec<Tensor>,
}

impl ParamSet {
    /// He-normal init for weights, zeros for biases.
    pub fn init(model: &ModelInfo, seed: u64) -> ParamSet {
        let mut rng = XorShift::new(seed);
        let tensors = model
            .param_shapes
            .iter()
            .map(|shape| {
                let n: usize = shape.iter().product();
                if shape.len() == 1 {
                    Tensor::zeros(shape)
                } else {
                    // conv OIHW: fan_in = I*k*k; dense (in,out): fan_in = in
                    let fan_in: usize = if shape.len() == 4 {
                        shape[1] * shape[2] * shape[3]
                    } else {
                        shape[0]
                    };
                    let std = (2.0f32 / fan_in as f32).sqrt();
                    let data = (0..n).map(|_| rng.normal() * std).collect();
                    Tensor::new(shape.clone(), data).unwrap()
                }
            })
            .collect();
        ParamSet { tensors }
    }

    pub fn n_conv(&self, model: &ModelInfo) -> usize {
        model.n_conv_params
    }

    /// Conv-layer parameters (flat [W, b] pairs in layer order).
    pub fn conv_slice(&self, model: &ModelInfo) -> &[Tensor] {
        &self.tensors[..model.n_conv_params]
    }

    pub fn fc_w(&self, model: &ModelInfo) -> &Tensor {
        &self.tensors[model.n_conv_params]
    }

    pub fn fc_b(&self, model: &ModelInfo) -> &Tensor {
        &self.tensors[model.n_conv_params + 1]
    }

    /// Zero-filled gradient accumulators of matching shapes.
    pub fn grad_zeros(&self) -> Vec<Tensor> {
        self.tensors
            .iter()
            .map(|t| Tensor::zeros(&t.shape))
            .collect()
    }

    pub fn size_bytes(&self) -> u64 {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }

    /// SGD step: p ← p − lr · g.
    pub fn sgd(&mut self, grads: &[Tensor], lr: f32) -> Result<()> {
        if grads.len() != self.tensors.len() {
            return Err(Error::Runtime(format!(
                "sgd: {} grads for {} params",
                grads.len(),
                self.tensors.len()
            )));
        }
        for (p, g) in self.tensors.iter_mut().zip(grads) {
            p.axpy(-lr, g)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ModelInfo;

    fn tiny_model() -> ModelInfo {
        ModelInfo {
            name: "t".into(),
            batch: 2,
            h: 8,
            w: 8,
            n_classes: 3,
            layers: vec![],
            heights: vec![8],
            w_out: 8,
            fc_in: 16,
            param_shapes: vec![vec![4, 3, 3, 3], vec![4], vec![16, 3], vec![3]],
            n_conv_params: 2,
        }
    }

    #[test]
    fn init_shapes_and_scaling() {
        let m = tiny_model();
        let p = ParamSet::init(&m, 0);
        assert_eq!(p.tensors.len(), 4);
        assert_eq!(p.tensors[0].shape, vec![4, 3, 3, 3]);
        assert!(p.tensors[1].data.iter().all(|&v| v == 0.0)); // bias zeros
        // He std ≈ sqrt(2/27) ≈ 0.27
        let w = &p.tensors[0].data;
        let var: f32 = w.iter().map(|v| v * v).sum::<f32>() / w.len() as f32;
        assert!((var.sqrt() - 0.27).abs() < 0.1, "std {}", var.sqrt());
    }

    #[test]
    fn sgd_moves_parameters() {
        let m = tiny_model();
        let mut p = ParamSet::init(&m, 0);
        let before = p.tensors[0].data[0];
        let mut g = p.grad_zeros();
        g[0].data[0] = 2.0;
        p.sgd(&g, 0.1).unwrap();
        assert!((p.tensors[0].data[0] - (before - 0.2)).abs() < 1e-6);
    }

    #[test]
    fn sgd_arity_mismatch_errors() {
        let m = tiny_model();
        let mut p = ParamSet::init(&m, 0);
        assert!(p.sgd(&[], 0.1).is_err());
    }
}
