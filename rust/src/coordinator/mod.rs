//! L3 coordinator: the live row-centric training scheduler.
//!
//! This is the runtime realization of Algorithm 1: FP walks the rows of
//! each segment through the PJRT row executables, releasing feature maps
//! eagerly; the concatenated z^L feeds the FC head; BP re-walks the rows in
//! reverse, recomputing slabs *inside* the row_bwd executables and
//! accumulating parameter gradients across rows.  Python is never invoked —
//! only the AOT artifacts are.
//!
//! The step's dataflow is one `rowir::RowProgram` (docs/ROWIR.md); the
//! trainer drives it.  Serial (the default) interprets the program in
//! node-id order (`rowir::interp`); `Trainer::set_sched` switches to the
//! pipelined row scheduler (`crate::sched`) or the multi-device sharded
//! executor (`crate::shard`), which execute the *same* program on worker
//! threads with bit-identical results (docs/SCHEDULER.md).
//!
//! Four execution modes mirror the paper's Fig. 11 branches plus Base:
//! * [`Mode::Base`]      — column-centric oracle (1 executable/step)
//! * [`Mode::RowHybrid`] — OverL-H: halo slabs, checkpoint at pool2
//! * [`Mode::Tps`]       — 2PS FP (boundary caches handed row-to-row)
//! * [`Mode::Naive`]     — broken w/o-sharing ablation (closed padding)

pub mod optim;
pub mod params;
pub mod redundancy;
pub mod trainer;

pub use optim::{Optimizer, OptimizerKind};
pub use params::ParamSet;
pub use trainer::{
    naive_row_extents, train_loop, Mode, Recalibration, ShardState, StepPlan, StepStats, Trainer,
};
