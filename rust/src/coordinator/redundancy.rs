//! Redundancy accounting for overlapping partitioning (paper §IV-B).
//!
//! The paper de-duplicates weight-gradient contributions from replicated
//! halo rows by *recording the redundant times and averaging the
//! accumulated sum*.  Our live path instead partitions the cotangent δ^L by
//! row (never replicating it), which is exact by linearity and needs no
//! averaging (DESIGN.md §5) — but the counting machinery is still the
//! source of the OD metrics in Figs. 9/10, and this module implements it
//! faithfully so the paper-faithful variant can be expressed and tested.

use crate::runtime::manifest::SegmentInfo;

/// Per-output-row computation multiplicity for one segment layer.
///
/// `counts[i]` = how many rows compute output row `i` of that layer; 1 =
/// exclusive, ≥2 = replicated (the Fig. 5 shared receptive field).
pub fn row_multiplicity(seg: &SegmentInfo, layer_idx: usize, h_out: usize) -> Vec<u32> {
    let mut counts = vec![0u32; h_out];
    for row in &seg.rows {
        let link = &row.chain[layer_idx];
        for i in link.out_iv[0]..link.out_iv[1] {
            counts[i] += 1;
        }
    }
    counts
}

/// Total replicated rows across a segment (the OD row counter of Fig. 9).
pub fn redundant_rows(seg: &SegmentInfo, heights_out: &[usize]) -> u64 {
    let mut total = 0u64;
    for (idx, &h_out) in heights_out.iter().enumerate() {
        total += row_multiplicity(seg, idx, h_out)
            .iter()
            .map(|&c| c.saturating_sub(1) as u64)
            .sum::<u64>();
    }
    total
}

/// The paper's count-and-average correction: given per-row contributions
/// `parts` to a value computed with multiplicity `mult` (every row that
/// touched a replicated region added its share), the corrected sum divides
/// each region's accumulation by its multiplicity.  For a scalar reduced
/// over rows this collapses to `sum(parts[i] / mult[i])`.
pub fn average_by_multiplicity(parts: &[f32], mult: &[u32]) -> f32 {
    assert_eq!(parts.len(), mult.len());
    parts
        .iter()
        .zip(mult)
        .map(|(&p, &m)| if m == 0 { 0.0 } else { p / m as f32 })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ChainLink, RowInfo, SegmentInfo};

    fn seg_two_rows() -> SegmentInfo {
        // one conv layer, h_out = 4; rows produce [0,3) and [1,4): rows 1-2
        // are replicated (multiplicity 2)
        let mk_row = |out: [usize; 2]| RowInfo {
            out_iv: out,
            in_iv: out,
            chain: vec![ChainLink {
                in_iv: out,
                out_iv: out,
                pad_top: 0,
                pad_bottom: 0,
            }],
        };
        SegmentInfo {
            name: "s".into(),
            h_in: 4,
            h_out: 4,
            c_in: 1,
            c_out: 1,
            param_lo: 0,
            param_hi: 2,
            rows: vec![mk_row([0, 3]), mk_row([1, 4])],
        }
    }

    #[test]
    fn multiplicity_counts_overlap() {
        let seg = seg_two_rows();
        assert_eq!(row_multiplicity(&seg, 0, 4), vec![1, 2, 2, 1]);
        assert_eq!(redundant_rows(&seg, &[4]), 2);
    }

    #[test]
    fn averaging_recovers_exact_value() {
        // replicated rows contribute twice; averaging recovers the truth
        let truth = [1.0f32, 2.0, 3.0, 4.0];
        let mult = [1u32, 2, 2, 1];
        let accumulated: Vec<f32> = truth
            .iter()
            .zip(&mult)
            .map(|(&t, &m)| t * m as f32)
            .collect();
        let corrected = average_by_multiplicity(&accumulated, &mult);
        assert!((corrected - truth.iter().sum::<f32>()).abs() < 1e-6);
    }
}
