//! Optimizers over [`ParamSet`] gradients.
//!
//! The paper trains with plain SGD; momentum and Adam are provided because
//! any real adopter needs them — and because optimizer state is part of ξ
//! (always-resident bytes), which the planners must account for: see
//! [`Optimizer::state_bytes`] and `Strategy::xi`.

use crate::error::{Error, Result};
use crate::runtime::Tensor;

use super::ParamSet;

/// Optimizer algorithm + hyper-parameters.
#[derive(Debug, Clone)]
pub enum OptimizerKind {
    Sgd,
    Momentum { beta: f32 },
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

/// Stateful optimizer over a fixed parameter layout.
#[derive(Debug)]
pub struct Optimizer {
    pub kind: OptimizerKind,
    pub lr: f32,
    /// first-moment buffers (momentum / Adam m)
    m: Vec<Tensor>,
    /// second-moment buffers (Adam v)
    v: Vec<Tensor>,
    t: u64,
}

impl Optimizer {
    pub fn sgd(lr: f32) -> Optimizer {
        Optimizer {
            kind: OptimizerKind::Sgd,
            lr,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }

    pub fn momentum(lr: f32, beta: f32) -> Optimizer {
        Optimizer {
            kind: OptimizerKind::Momentum { beta },
            lr,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }

    pub fn adam(lr: f32) -> Optimizer {
        Optimizer {
            kind: OptimizerKind::Adam {
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
            },
            lr,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }

    fn ensure_state(&mut self, params: &ParamSet) {
        let need_m = !matches!(self.kind, OptimizerKind::Sgd);
        let need_v = matches!(self.kind, OptimizerKind::Adam { .. });
        if need_m && self.m.is_empty() {
            self.m = params.grad_zeros();
        }
        if need_v && self.v.is_empty() {
            self.v = params.grad_zeros();
        }
    }

    /// Bytes of optimizer state — goes into ξ for planning purposes.
    pub fn state_bytes(&self, params: &ParamSet) -> u64 {
        let per = params.size_bytes();
        match self.kind {
            OptimizerKind::Sgd => 0,
            OptimizerKind::Momentum { .. } => per,
            OptimizerKind::Adam { .. } => 2 * per,
        }
    }

    /// Apply one update: params ← params − lr · direction(grads).
    pub fn step(&mut self, params: &mut ParamSet, grads: &[Tensor]) -> Result<()> {
        if grads.len() != params.tensors.len() {
            return Err(Error::Runtime(format!(
                "optimizer: {} grads for {} params",
                grads.len(),
                params.tensors.len()
            )));
        }
        self.ensure_state(params);
        self.t += 1;
        match self.kind {
            OptimizerKind::Sgd => params.sgd(grads, self.lr),
            OptimizerKind::Momentum { beta } => {
                for ((p, g), m) in params.tensors.iter_mut().zip(grads).zip(&mut self.m) {
                    for (mi, gi) in m.data.iter_mut().zip(&g.data) {
                        *mi = beta * *mi + gi;
                    }
                    p.axpy(-self.lr, m)?;
                }
                Ok(())
            }
            OptimizerKind::Adam { beta1, beta2, eps } => {
                let bc1 = 1.0 - beta1.powi(self.t as i32);
                let bc2 = 1.0 - beta2.powi(self.t as i32);
                for (((p, g), m), v) in params
                    .tensors
                    .iter_mut()
                    .zip(grads)
                    .zip(&mut self.m)
                    .zip(&mut self.v)
                {
                    for ((pi, gi), (mi, vi)) in p
                        .data
                        .iter_mut()
                        .zip(&g.data)
                        .zip(m.data.iter_mut().zip(v.data.iter_mut()))
                    {
                        *mi = beta1 * *mi + (1.0 - beta1) * gi;
                        *vi = beta2 * *vi + (1.0 - beta2) * gi * gi;
                        let mh = *mi / bc1;
                        let vh = *vi / bc2;
                        *pi -= self.lr * mh / (vh.sqrt() + eps);
                    }
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ModelInfo;

    fn params() -> ParamSet {
        let model = ModelInfo {
            name: "t".into(),
            batch: 1,
            h: 4,
            w: 4,
            n_classes: 2,
            layers: vec![],
            heights: vec![4],
            w_out: 4,
            fc_in: 4,
            param_shapes: vec![vec![2, 2]],
            n_conv_params: 0,
        };
        ParamSet::init(&model, 1)
    }

    fn grad_ones(p: &ParamSet) -> Vec<Tensor> {
        p.tensors
            .iter()
            .map(|t| Tensor::new(t.shape.clone(), vec![1.0; t.len()]).unwrap())
            .collect()
    }

    #[test]
    fn sgd_matches_param_set_sgd() {
        let mut a = params();
        let mut b = params();
        let g = grad_ones(&a);
        Optimizer::sgd(0.1).step(&mut a, &g).unwrap();
        b.sgd(&g, 0.1).unwrap();
        assert_eq!(a.tensors[0].data, b.tensors[0].data);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut p = params();
        let before = p.tensors[0].data[0];
        let g = grad_ones(&p);
        let mut opt = Optimizer::momentum(0.1, 0.9);
        opt.step(&mut p, &g).unwrap(); // v=1, Δ=0.1
        opt.step(&mut p, &g).unwrap(); // v=1.9, Δ=0.19
        let moved = before - p.tensors[0].data[0];
        assert!((moved - 0.29).abs() < 1e-6, "{moved}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // minimize f(x) = x² elementwise from x0; Adam should approach 0
        let mut p = params();
        let mut opt = Optimizer::adam(0.05);
        for _ in 0..400 {
            let g: Vec<Tensor> = p
                .tensors
                .iter()
                .map(|t| {
                    Tensor::new(t.shape.clone(), t.data.iter().map(|x| 2.0 * x).collect())
                        .unwrap()
                })
                .collect();
            opt.step(&mut p, &g).unwrap();
        }
        assert!(p.tensors[0].data.iter().all(|x| x.abs() < 1e-2));
    }

    #[test]
    fn state_bytes_scale_with_kind() {
        let p = params();
        assert_eq!(Optimizer::sgd(0.1).state_bytes(&p), 0);
        assert_eq!(Optimizer::momentum(0.1, 0.9).state_bytes(&p), p.size_bytes());
        assert_eq!(Optimizer::adam(0.1).state_bytes(&p), 2 * p.size_bytes());
    }

    #[test]
    fn arity_mismatch_errors() {
        let mut p = params();
        assert!(Optimizer::adam(0.1).step(&mut p, &[]).is_err());
    }
}
