//! OverL — Overlapping row partitioning (paper §IV-B).
//!
//! The segment output is divided evenly; each row's input slab is the exact
//! preimage of its output interval (Eq. 15 generalized by
//! `shapes::slab_chain`), so consecutive slabs *replicate* the halo rows
//! and every row runs with zero coordination.  The price is redundant
//! compute on the replicated rows (ι) and the replicated bytes themselves
//! (OD) — both counted here for Figs. 8–10.

use crate::costmodel::CostCounters;
use crate::error::{Error, Result};
use crate::memory::Schedule;
use crate::model::Network;
use crate::shapes::{even_partition, slab_chain, Interval, SlabChain};

use super::{slab_bytes, with_iteration_frame, RowCentric, SegmentView};

/// Per-segment OverL geometry.
pub struct OverlapSegment<'n> {
    pub seg: SegmentView<'n>,
    pub n: usize,
    /// output interval per row
    pub ivs: Vec<Interval>,
    /// slab chain per row
    pub chains: Vec<SlabChain>,
}

/// Largest N ≤ `target` whose partitioning still has at least one
/// non-replicated row somewhere — beyond that every slab covers the whole
/// input and the scheme is pure overhead (the paper's `N > H / o_r^0`
/// ineffectiveness, §IV-B "Impact of N").  Growing-but-finite halos are
/// *allowed*: they are what produces the Fig. 10 U-shape.
pub fn max_effective_n(seg: &SegmentView<'_>, target: usize) -> usize {
    let cap = target.min(seg.h_out()).max(1);
    (2..=cap)
        .rev()
        .find(|&n| {
            let ivs = even_partition(seg.h_out(), n);
            ivs.iter().any(|&iv| {
                let ch = slab_chain(seg.layers, &seg.heights, iv);
                let (a, b) = ch[0].in_iv;
                (b - a) < seg.h_in()
            })
        })
        .unwrap_or(1)
}

/// Strict effectiveness for *flat-prefix* selection: every slab's halo
/// must stay below the row's own input share (the paper's N ≤ H/o_r^0
/// operating regime, §IV-B) — beyond this point partitioning still works
/// but replication dominates, which is -H territory.
pub fn prefix_effective(seg: &SegmentView<'_>, target: usize) -> bool {
    let n = target.min(seg.h_out());
    if n < 2 {
        return false;
    }
    let ivs = even_partition(seg.h_out(), n);
    let share = (seg.h_in() + n - 1) / n;
    ivs.iter().all(|&iv| {
        let ch = slab_chain(seg.layers, &seg.heights, iv);
        let slab = ch[0].in_iv.1 - ch[0].in_iv.0;
        slab.saturating_sub(share) < share.max(1)
    })
}

/// Plan per-segment geometry, degrading N per segment to the largest
/// effective value (§IV-B; the hybrids exist to keep this close to the
/// target by truncating depth).
pub fn plan<'n>(
    rc: &RowCentric,
    net: &'n Network,
    h: usize,
    w: usize,
) -> Result<Vec<OverlapSegment<'n>>> {
    let mut out = Vec::new();
    let segs = rc.segments(net, h, w);
    let targets = rc.segment_targets(segs.len());
    for (seg, target) in segs.into_iter().zip(targets) {
        if seg.layers.is_empty() {
            return Err(Error::InfeasiblePlan("empty segment".into()));
        }
        let h_out = seg.h_out();
        let n = max_effective_n(&seg, target);
        if n == 1 {
            out.push(OverlapSegment {
                seg,
                n: 1,
                ivs: vec![(0, h_out)],
                chains: Vec::new(),
            });
            continue;
        }
        let ivs = even_partition(h_out, n);
        let chains: Vec<SlabChain> = ivs
            .iter()
            .map(|&iv| slab_chain(seg.layers, &seg.heights, iv))
            .collect();
        out.push(OverlapSegment {
            seg,
            n,
            ivs,
            chains,
        });
    }
    Ok(out)
}

pub fn schedule(rc: &RowCentric, net: &Network, b: usize, h: usize, w: usize) -> Result<Schedule> {
    let segs = plan(rc, net, h, w)?;
    let last_si = segs.len() - 1;
    with_iteration_frame(net, b, h, w, |s| {
        // ---------------- FP ----------------
        for (si, os) in segs.iter().enumerate() {
            s.mark(format!("fp.seg{si}"));
            let seg = &os.seg;
            let nl = seg.layers.len();
            if os.n == 1 {
                for (idx, l) in seg.layers.iter().enumerate() {
                    s.alloc(
                        format!("s{si}.l{idx}"),
                        slab_bytes(b, l.c_out, seg.heights[idx + 1], seg.widths[idx + 1]),
                    );
                    if idx > 0 {
                        s.free(format!("s{si}.l{}", idx - 1));
                    }
                }
                s.alloc(
                    format!("ck{si}"),
                    slab_bytes(b, seg.c_out(), seg.h_out(), *seg.widths.last().unwrap()),
                );
                if nl > 0 {
                    s.free(format!("s{si}.l{}", nl - 1));
                }
                continue;
            }
            for (r, chain) in os.chains.iter().enumerate() {
                s.mark(format!("fp.seg{si}.row{r}"));
                // the replicated input slab is materialized per row (the
                // "pull before training" copy of Fig. 5)
                s.alloc(
                    format!("s{si}.r{r}.slab"),
                    slab_bytes(
                        b,
                        seg.c_in(),
                        chain[0].in_iv.1 - chain[0].in_iv.0,
                        seg.widths[0],
                    ),
                );
                for (idx, link) in chain.iter().enumerate() {
                    let l = &seg.layers[idx];
                    let rows = link.out_iv.1 - link.out_iv.0;
                    s.alloc(
                        format!("s{si}.r{r}.l{idx}"),
                        slab_bytes(b, l.c_out, rows, seg.widths[idx + 1]),
                    );
                    if idx == 0 {
                        s.free(format!("s{si}.r{r}.slab"));
                    } else {
                        s.free(format!("s{si}.r{r}.l{}", idx - 1));
                    }
                }
            }
            // concat rows into checkpoint / z^L
            s.alloc(
                format!("ck{si}"),
                slab_bytes(b, seg.c_out(), seg.h_out(), *seg.widths.last().unwrap()),
            );
            for r in 0..os.n {
                s.free(format!("s{si}.r{r}.l{}", nl - 1));
            }
        }

        // ---------------- head + δ^L ----------------
        s.mark("head");
        let zl_bytes = slab_bytes(
            b,
            segs[last_si].seg.c_out(),
            segs[last_si].seg.h_out(),
            *segs[last_si].seg.widths.last().unwrap(),
        );
        s.alloc("deltaL", zl_bytes);

        // ---------------- BP ----------------
        for (si, os) in segs.iter().enumerate().rev() {
            s.mark(format!("bp.seg{si}"));
            let seg = &os.seg;
            let nl = seg.layers.len();
            let delta_in = if si == last_si {
                "deltaL".to_string()
            } else {
                format!("dck{si}")
            };
            if si > 0 {
                s.alloc(
                    format!("dck{}", si - 1),
                    slab_bytes(b, seg.c_in(), seg.h_in(), seg.widths[0]),
                );
            }
            if os.n == 1 {
                for (idx, l) in seg.layers.iter().enumerate() {
                    s.alloc(
                        format!("s{si}.bp.l{idx}"),
                        slab_bytes(b, l.c_out, seg.heights[idx + 1], seg.widths[idx + 1]),
                    );
                }
                for idx in (0..nl).rev() {
                    let l = &seg.layers[idx];
                    s.alloc(
                        format!("s{si}.bp.d{idx}"),
                        slab_bytes(b, l.c_in, seg.heights[idx], seg.widths[idx]),
                    );
                    s.free(format!("s{si}.bp.l{idx}"));
                    if idx < nl - 1 {
                        s.free(format!("s{si}.bp.d{}", idx + 1));
                    }
                }
                s.free(format!("s{si}.bp.d0"));
            } else {
                for (r, chain) in os.chains.iter().enumerate().rev() {
                    s.mark(format!("bp.seg{si}.row{r}"));
                    // recompute & keep all slab maps of row r
                    s.alloc(
                        format!("s{si}.bp.r{r}.slab"),
                        slab_bytes(
                            b,
                            seg.c_in(),
                            chain[0].in_iv.1 - chain[0].in_iv.0,
                            seg.widths[0],
                        ),
                    );
                    for (idx, link) in chain.iter().enumerate() {
                        let l = &seg.layers[idx];
                        let rows = link.out_iv.1 - link.out_iv.0;
                        s.alloc(
                            format!("s{si}.bp.r{r}.l{idx}"),
                            slab_bytes(b, l.c_out, rows, seg.widths[idx + 1]),
                        );
                    }
                    // δ slabs back down the chain
                    for idx in (0..nl).rev() {
                        let l = &seg.layers[idx];
                        let link = &chain[idx];
                        let rows = link.in_iv.1 - link.in_iv.0;
                        s.alloc(
                            format!("s{si}.bp.r{r}.d{idx}"),
                            slab_bytes(b, l.c_in, rows, seg.widths[idx]),
                        );
                        s.free(format!("s{si}.bp.r{r}.l{idx}"));
                        if idx < nl - 1 {
                            s.free(format!("s{si}.bp.r{r}.d{}", idx + 1));
                        }
                    }
                    s.free(format!("s{si}.bp.r{r}.d0"));
                    s.free(format!("s{si}.bp.r{r}.slab"));
                }
            }
            s.free(delta_in);
            if si > 0 {
                s.free(format!("ck{}", si - 1));
            }
        }
        s.free(format!("ck{last_si}"));
        Ok(())
    })
}

pub fn cost(rc: &RowCentric, net: &Network, b: usize, h: usize, w: usize) -> Result<CostCounters> {
    let segs = plan(rc, net, h, w)?;
    let tau: u64 = net.conv_flops(b, h, w) + net.fc_flops(b);
    let mut c = CostCounters {
        fp_flops: tau,
        bp_flops: 2 * tau,
        recompute_flops: net.conv_flops(b, h, w),
        ..Default::default()
    };
    for os in &segs {
        if os.n <= 1 {
            continue;
        }
        let seg = &os.seg;
        let seg_conv: u64 = seg
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| l.flops(b, seg.heights[i + 1], seg.widths[i + 1]))
            .sum();
        c.slab_flops += 4 * seg_conv;
        // ι: rows computed by *both* of two adjacent rows (the replicated
        // receptive-field region of Fig. 5), per layer; paid in FP, in the
        // BP recompute, and twice in BP (paper: 4ι)
        let mut iota = 0u64;
        for r in 0..os.n - 1 {
            let (a, bnext) = (&os.chains[r], &os.chains[r + 1]);
            // replicated *input* rows (o^0, Eq. 15) count toward OD
            let ov_in = a[0].in_iv.1.saturating_sub(bnext[0].in_iv.0);
            c.overlap_bytes += slab_bytes(b, seg.c_in(), ov_in, seg.widths[0]);
            c.overlap_rows += ov_in as u64;
            for (idx, l) in seg.layers.iter().enumerate() {
                let ov = a[idx].out_iv.1.saturating_sub(bnext[idx].out_iv.0);
                iota += l.flops(b, ov, seg.widths[idx + 1]);
                c.overlap_bytes += slab_bytes(b, l.c_out, ov, seg.widths[idx + 1]);
                c.overlap_rows += ov as u64;
            }
        }
        c.overlap_flops += 4 * iota;
        c.slab_flops += 4 * iota;
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::sim::simulate;
    use crate::model::{minivgg, vgg16};
    use crate::planner::{RowCentric, RowMode, Strategy};

    #[test]
    fn hybrid_minivgg_schedule_replays_clean() {
        let net = minivgg();
        let rc = RowCentric::hybrid(RowMode::Overlap, 4, vec![4]);
        let s = rc.schedule(&net, 8, 32, 32).unwrap();
        let rep = simulate(&s).unwrap();
        assert_eq!(rep.final_bytes, 0, "leak in OverL schedule");
    }

    #[test]
    fn flat_overl_partitions_only_an_effective_prefix() {
        // full-depth halos through two pools ≈ 19+ rows of 32: the flat
        // plan must confine partitioning to a prefix (paper Table I) and
        // keep the tail column-centric; the hybrid covers more layers
        let net = minivgg();
        let flat = RowCentric::new(RowMode::Overlap, 4);
        let eff = flat.effective_rows(&net, 32, 32);
        assert!(eff.len() >= 2, "flat plan should split off a prefix: {eff:?}");
        assert_eq!(*eff.last().unwrap(), 1, "tail must stay column: {eff:?}");
        let hybrid = RowCentric::hybrid(RowMode::Overlap, 4, vec![4]);
        let (lf, rf) = flat.table1_metrics(&net, 32, 32);
        let (lh, rh) = hybrid.table1_metrics(&net, 32, 32);
        assert!(lh >= lf && rh >= rf, "({lf},{rf}) vs ({lh},{rh})");
        // both replay clean and both beat Base
        let base = simulate(&crate::baselines::Base.schedule(&net, 8, 32, 32).unwrap())
            .unwrap()
            .peak_bytes;
        for rc in [flat, hybrid] {
            let rep = simulate(&rc.schedule(&net, 8, 32, 32).unwrap()).unwrap();
            assert_eq!(rep.final_bytes, 0);
            assert!(rep.peak_bytes < base, "{} vs base {base}", rep.peak_bytes);
        }
    }

    #[test]
    fn overl_h_reduces_peak_on_vgg16() {
        let net = vgg16();
        let base = crate::baselines::Base.schedule(&net, 8, 224, 224).unwrap();
        let base_peak = simulate(&base).unwrap().peak_bytes;
        let cks = crate::planner::checkpoint::pool_boundary_checkpoints(&net, 4);
        let rc = RowCentric::hybrid(RowMode::Overlap, 8, cks);
        let peak = simulate(&rc.schedule(&net, 8, 224, 224).unwrap())
            .unwrap()
            .peak_bytes;
        assert!(
            (peak as f64) < base_peak as f64 * 0.5,
            "OverL-H peak {peak} vs Base {base_peak}"
        );
    }

    #[test]
    fn overlap_cost_counts_iota_and_od() {
        let net = vgg16();
        let cks = crate::planner::checkpoint::pool_boundary_checkpoints(&net, 4);
        let c4 = RowCentric::hybrid(RowMode::Overlap, 4, cks.clone())
            .cost(&net, 8, 224, 224)
            .unwrap();
        let c8 = RowCentric::hybrid(RowMode::Overlap, 8, cks)
            .cost(&net, 8, 224, 224)
            .unwrap();
        assert!(c4.overlap_flops > 0);
        assert!(c8.overlap_rows > c4.overlap_rows, "OD grows with N (Fig. 9)");
        assert_eq!(c4.interruptions, 0, "OverL has no interruptions");
    }
}
