//! 2PS — Two-Phase Sharing row partitioning (paper §IV-A).
//!
//! Rows are skewed: ownership boundaries follow the backward height
//! recursion (Eqs. 11/13/14, generalized in `shapes::tps_boundaries`).
//! Consecutive rows share a (k−s)-row cache per conv layer, preserved
//! across both phases (FP hand-off *and* BP recompute), which is exactly
//! the `B(N−1)Σ(k^l−s^l)W^l C^l` term of Eq. (12).  Cache extract/concat
//! operations are counted as coordination interruptions (CI, Fig. 9).

use crate::costmodel::CostCounters;
use crate::error::{Error, Result};
use crate::memory::Schedule;
use crate::model::Network;
use crate::shapes::{even_partition, tps_boundaries, tps_cache_rows};

use super::{slab_bytes, with_iteration_frame, RowCentric, SegmentView};

/// Per-segment 2PS geometry, shared by schedule() and cost().
pub struct TpsSegment<'n> {
    pub seg: SegmentView<'n>,
    /// effective rows in this segment (1 = not partitioned)
    pub n: usize,
    /// bounds[layer_input][cut]
    pub bounds: Vec<Vec<usize>>,
    /// caches[r][layer] for r in 1..n
    pub caches: Vec<Vec<Option<(usize, usize)>>>,
}

/// Feasibility of one candidate N on a segment: every row must own at
/// least one input row at every layer, otherwise the backward recursion
/// degenerates (the paper's `(N−1)(k−s) > max{H}` failure — §IV-A).
fn feasible_bounds(
    seg: &SegmentView<'_>,
    n: usize,
) -> Option<(Vec<usize>, Vec<Vec<usize>>)> {
    let h_out = seg.h_out();
    let cuts: Vec<usize> = even_partition(h_out, n)
        .iter()
        .map(|iv| iv.0)
        .chain(std::iter::once(h_out))
        .collect();
    let bounds = tps_boundaries(seg.layers, &seg.heights, &cuts);
    for layer_cuts in &bounds {
        for r in 0..n {
            if layer_cuts[r] >= layer_cuts[r + 1] {
                return None;
            }
        }
    }
    Some((cuts, bounds))
}

/// Largest feasible N ≤ `target` for this segment (≥ 1).  This is the
/// paper's adaptive response to the depth constraint: the hybrid variants
/// exist exactly because deeper segments force N down (§IV-A "Impact of N").
pub fn max_feasible_n(seg: &SegmentView<'_>, target: usize) -> usize {
    let cap = target.min(seg.h_out()).max(1);
    (2..=cap)
        .rev()
        .find(|&n| feasible_bounds(seg, n).is_some())
        .unwrap_or(1)
}

/// Plan the per-segment geometry, degrading N per segment to the largest
/// feasible value.  Errors only if even N=1 cannot be expressed.
pub fn plan<'n>(
    rc: &RowCentric,
    net: &'n Network,
    h: usize,
    w: usize,
) -> Result<Vec<TpsSegment<'n>>> {
    let mut out = Vec::new();
    let segs = rc.segments(net, h, w);
    let targets = rc.segment_targets(segs.len());
    for (seg, target) in segs.into_iter().zip(targets) {
        if seg.layers.is_empty() {
            return Err(Error::InfeasiblePlan("empty segment".into()));
        }
        let n = max_feasible_n(&seg, target);
        if n == 1 {
            out.push(TpsSegment {
                seg,
                n: 1,
                bounds: Vec::new(),
                caches: Vec::new(),
            });
            continue;
        }
        let (_cuts, bounds) = feasible_bounds(&seg, n).expect("checked by max_feasible_n");
        let caches = (1..n)
            .map(|r| tps_cache_rows(seg.layers, &bounds, r))
            .collect();
        out.push(TpsSegment {
            seg,
            n,
            bounds,
            caches,
        });
    }
    Ok(out)
}

fn own_rows(bounds: &[Vec<usize>], idx: usize, r: usize) -> usize {
    bounds[idx][r + 1] - bounds[idx][r]
}

pub fn schedule(rc: &RowCentric, net: &Network, b: usize, h: usize, w: usize) -> Result<Schedule> {
    let segs = plan(rc, net, h, w)?;
    let last_si = segs.len() - 1;
    with_iteration_frame(net, b, h, w, |s| {
        // ---------------- FP ----------------
        for (si, ts) in segs.iter().enumerate() {
            s.mark(format!("fp.seg{si}"));
            let seg = &ts.seg;
            let nl = seg.layers.len();
            if ts.n == 1 {
                // unpartitioned segment: column-centric within, keep only
                // the working pair + the segment output (checkpoint / z^L)
                for (idx, l) in seg.layers.iter().enumerate() {
                    s.alloc(
                        format!("s{si}.l{idx}"),
                        slab_bytes(b, l.c_out, seg.heights[idx + 1], seg.widths[idx + 1]),
                    );
                    if idx > 0 {
                        s.free(format!("s{si}.l{}", idx - 1));
                    }
                }
                // rename: the final buffer doubles as checkpoint/zL
                s.alloc(
                    format!("ck{si}"),
                    slab_bytes(b, seg.c_out(), seg.h_out(), *seg.widths.last().unwrap()),
                );
                if nl > 0 {
                    s.free(format!("s{si}.l{}", nl - 1));
                }
                continue;
            }
            for r in 0..ts.n {
                s.mark(format!("fp.seg{si}.row{r}"));
                // caches produced by this row for row r+1 (alive until the
                // consumer's BP — "preserved in FP and BP", §IV-A)
                if r + 1 < ts.n {
                    for (idx, c) in ts.caches[r + 1 - 1].iter().enumerate() {
                        if let Some((a, e)) = c {
                            s.alloc(
                                format!("s{si}.cache.r{}.l{idx}", r + 1),
                                slab_bytes(b, seg.layers[idx].c_in, e - a, seg.widths[idx]),
                            );
                        }
                    }
                }
                for idx in 0..nl {
                    let rows = own_rows(&ts.bounds, idx + 1, r);
                    let l = &seg.layers[idx];
                    let is_last = idx == nl - 1;
                    let id = if is_last {
                        format!("s{si}.zrow{r}")
                    } else {
                        format!("s{si}.r{r}.l{idx}")
                    };
                    s.alloc(id, slab_bytes(b, l.c_out, rows, seg.widths[idx + 1]));
                    if idx > 0 {
                        s.free(format!("s{si}.r{r}.l{}", idx - 1));
                    }
                }
            }
            // concat the segment output rows into the checkpoint / z^L buffer
            s.alloc(
                format!("ck{si}"),
                slab_bytes(b, seg.c_out(), seg.h_out(), *seg.widths.last().unwrap()),
            );
            for r in 0..ts.n {
                s.free(format!("s{si}.zrow{r}"));
            }
        }

        // ---------------- head + δ^L ----------------
        s.mark("head");
        let zl_bytes = slab_bytes(
            b,
            segs[last_si].seg.c_out(),
            segs[last_si].seg.h_out(),
            *segs[last_si].seg.widths.last().unwrap(),
        );
        s.alloc("deltaL", zl_bytes);

        // ---------------- BP ----------------
        for (si, ts) in segs.iter().enumerate().rev() {
            s.mark(format!("bp.seg{si}"));
            let seg = &ts.seg;
            let nl = seg.layers.len();
            // δ buffer entering this segment (δ^L for the last)
            let delta_in = if si == last_si {
                "deltaL".to_string()
            } else {
                format!("dck{si}")
            };
            // δ to hand to the previous segment (accumulated across rows)
            if si > 0 {
                s.alloc(
                    format!("dck{}", si - 1),
                    slab_bytes(b, seg.c_in(), seg.h_in(), seg.widths[0]),
                );
            }
            if ts.n == 1 {
                // column BP within the segment: recompute all maps, then walk back
                for (idx, l) in seg.layers.iter().enumerate() {
                    s.alloc(
                        format!("s{si}.bp.l{idx}"),
                        slab_bytes(b, l.c_out, seg.heights[idx + 1], seg.widths[idx + 1]),
                    );
                }
                for idx in (0..nl).rev() {
                    let l = &seg.layers[idx];
                    s.alloc(
                        format!("s{si}.bp.d{idx}"),
                        slab_bytes(b, l.c_in, seg.heights[idx], seg.widths[idx]),
                    );
                    s.free(format!("s{si}.bp.l{idx}"));
                    if idx < nl - 1 {
                        s.free(format!("s{si}.bp.d{}", idx + 1));
                    }
                }
                s.free(format!("s{si}.bp.d0"));
            } else {
                for r in (0..ts.n).rev() {
                    s.mark(format!("bp.seg{si}.row{r}"));
                    // recompute & keep all own slabs of row r (Eq. 8)
                    for idx in 0..nl {
                        let l = &seg.layers[idx];
                        let rows = own_rows(&ts.bounds, idx + 1, r);
                        s.alloc(
                            format!("s{si}.bp.r{r}.l{idx}"),
                            slab_bytes(b, l.c_out, rows, seg.widths[idx + 1]),
                        );
                    }
                    // δ slabs, two live at a time
                    for idx in (0..nl).rev() {
                        let l = &seg.layers[idx];
                        let rows = own_rows(&ts.bounds, idx, r);
                        s.alloc(
                            format!("s{si}.bp.r{r}.d{idx}"),
                            slab_bytes(b, l.c_in, rows, seg.widths[idx]),
                        );
                        s.free(format!("s{si}.bp.r{r}.l{idx}"));
                        if idx < nl - 1 {
                            s.free(format!("s{si}.bp.r{r}.d{}", idx + 1));
                        }
                    }
                    s.free(format!("s{si}.bp.r{r}.d0"));
                    // caches consumed by row r are no longer needed
                    if r >= 1 {
                        for (idx, c) in ts.caches[r - 1].iter().enumerate() {
                            if c.is_some() {
                                s.free(format!("s{si}.cache.r{r}.l{idx}"));
                            }
                        }
                    }
                }
            }
            // the δ that fed this segment is consumed
            s.free(delta_in);
            // the checkpoint feeding this segment's recompute is consumed
            // (segment 0 recomputes from the input batch, freed by the frame)
            if si > 0 {
                s.free(format!("ck{}", si - 1));
            }
        }
        s.free(format!("ck{last_si}"));
        Ok(())
    })
}

pub fn cost(rc: &RowCentric, net: &Network, b: usize, h: usize, w: usize) -> Result<CostCounters> {
    let segs = plan(rc, net, h, w)?;
    let hs = net.heights(h);
    let ws = net.widths(w);
    let tau: u64 = net.conv_flops(b, h, w) + net.fc_flops(b);
    let mut c = CostCounters {
        fp_flops: tau,
        bp_flops: 2 * tau,
        recompute_flops: net.conv_flops(b, h, w), // full re-FP during BP
        ..Default::default()
    };
    let _ = (&hs, &ws);
    for ts in &segs {
        if ts.n <= 1 {
            continue;
        }
        let seg = &ts.seg;
        // every conv executed as slabs, FP + recompute + BP
        let seg_conv: u64 = seg
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| l.flops(b, seg.heights[i + 1], seg.widths[i + 1]))
            .sum();
        c.slab_flops += 4 * seg_conv;
        // CI: one extract + one concat per cached layer per consuming row,
        // in FP and again in the BP recompute
        for caches in &ts.caches {
            for (idx, cch) in caches.iter().enumerate() {
                if let Some((a, e)) = cch {
                    c.interruptions += 2 * 2;
                    c.sharing_bytes += slab_bytes(b, seg.layers[idx].c_in, e - a, seg.widths[idx]);
                }
            }
        }
    }
    // SD volume counted once; CI already includes both phases
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::sim::simulate;
    use crate::model::{minivgg, vgg16};
    use crate::planner::{RowCentric, RowMode, Strategy};

    #[test]
    fn minivgg_n2_schedule_replays_clean() {
        let net = minivgg();
        let rc = RowCentric::new(RowMode::TwoPhase, 2);
        let s = rc.schedule(&net, 8, 32, 32).unwrap();
        let rep = simulate(&s).unwrap();
        assert_eq!(rep.final_bytes, 0, "leak in 2PS schedule");
        assert!(rep.peak_bytes > 0);
    }

    #[test]
    fn deep_2ps_degrades_and_hybrid_recovers_rows() {
        // minivgg's 8-row output + 6-layer depth exhausts 2PS ownership
        // quickly (§IV-A "Impact of N"): full-depth N degrades...
        let net = minivgg();
        let rc = RowCentric::new(RowMode::TwoPhase, 4);
        let eff = rc.effective_rows(&net, 32, 32);
        // flat: a partitioned prefix + a column tail (Table I's "subset of
        // layers" for the plain variants)
        assert_eq!(*eff.last().unwrap(), 1, "tail must stay column: {eff:?}");
        let flat_rows: usize = eff.iter().sum();
        let s = rc.schedule(&net, 8, 32, 32).unwrap();
        assert_eq!(simulate(&s).unwrap().final_bytes, 0);
        // ...and checkpoints recover the granularity (Table I's story)
        let rch = RowCentric::hybrid(RowMode::TwoPhase, 4, vec![2, 4]);
        let (l_flat, r_flat) = rc.table1_metrics(&net, 32, 32);
        let (l_h, r_h) = rch.table1_metrics(&net, 32, 32);
        assert!(
            l_h >= l_flat && r_h > r_flat,
            "Table I: -H must dominate ({l_flat},{r_flat}) vs ({l_h},{r_h})"
        );
        let s = rch.schedule(&net, 8, 32, 32).unwrap();
        assert_eq!(simulate(&s).unwrap().final_bytes, 0);
        // VGG-16 at 224² replays clean too (large maps keep ownership alive)
        let net = vgg16();
        let rc = RowCentric::new(RowMode::TwoPhase, 8);
        let s = rc.schedule(&net, 8, 224, 224).unwrap();
        assert_eq!(simulate(&s).unwrap().final_bytes, 0);
    }

    #[test]
    fn partitioning_reduces_peak() {
        let net = minivgg();
        let base = crate::baselines::Base.schedule(&net, 8, 32, 32).unwrap();
        let base_peak = simulate(&base).unwrap().peak_bytes;
        let rc = RowCentric::new(RowMode::TwoPhase, 2);
        let peak = simulate(&rc.schedule(&net, 8, 32, 32).unwrap())
            .unwrap()
            .peak_bytes;
        assert!(
            peak < base_peak,
            "2PS peak {peak} should beat Base {base_peak}"
        );
    }

    #[test]
    fn cost_counts_interruptions_linear_in_n() {
        let net = minivgg();
        let cks = vec![2usize, 4];
        let c2 = RowCentric::hybrid(RowMode::TwoPhase, 2, cks.clone())
            .cost(&net, 8, 32, 32)
            .unwrap();
        let c3 = RowCentric::hybrid(RowMode::TwoPhase, 3, cks)
            .cost(&net, 8, 32, 32)
            .unwrap();
        assert!(c3.interruptions > c2.interruptions, "{:?} vs {:?}", c3.interruptions, c2.interruptions);
        assert!(c2.sharing_bytes > 0);
    }
}
