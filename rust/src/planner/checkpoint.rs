//! Checkpoint placement and segment views (Chen et al. [10] + §IV hybrids).
//!
//! A checkpoint at position `c` means the output feature map of layer
//! `c−1` (1-based: `heights[c]`) is *kept* for the whole iteration; the
//! hybrids row-partition each span between consecutive checkpoints
//! independently, which truncates the depth L that inflates 2PS cache
//! skew (Eq. 11–14) and OverL halos (Eq. 15).

use crate::model::{Layer, Network};

/// A contiguous span of the conv chain treated as one row-partitioned unit.
#[derive(Debug, Clone)]
pub struct SegmentView<'n> {
    /// global index of the first layer in the segment
    pub l0: usize,
    pub layers: &'n [Layer],
    /// per-layer input heights, len = layers.len() + 1
    pub heights: Vec<usize>,
    pub widths: Vec<usize>,
}

impl<'n> SegmentView<'n> {
    pub fn h_in(&self) -> usize {
        self.heights[0]
    }

    pub fn h_out(&self) -> usize {
        *self.heights.last().unwrap()
    }

    pub fn c_in(&self) -> usize {
        self.layers[0].c_in
    }

    pub fn c_out(&self) -> usize {
        self.layers.last().unwrap().c_out
    }
}

/// Split `net` at checkpoint positions (exclusive layer indices, sorted,
/// in (0, L)).  Empty -> one segment covering the whole chain.
pub fn split_segments<'n>(
    net: &'n Network,
    checkpoints: &[usize],
    h: usize,
    w: usize,
) -> Vec<SegmentView<'n>> {
    let hs = net.heights(h);
    let ws = net.widths(w);
    let mut cuts = vec![0usize];
    for &c in checkpoints {
        assert!(c > 0 && c < net.layers.len(), "checkpoint {c} out of range");
        assert!(*cuts.last().unwrap() < c, "checkpoints must be sorted/unique");
        cuts.push(c);
    }
    cuts.push(net.layers.len());
    cuts.windows(2)
        .map(|wd| {
            let (lo, hi) = (wd[0], wd[1]);
            SegmentView {
                l0: lo,
                layers: &net.layers[lo..hi],
                heights: hs[lo..=hi].to_vec(),
                widths: ws[lo..=hi].to_vec(),
            }
        })
        .collect()
}

/// Chen et al.'s preferred √n spacing: checkpoints every ⌈√L⌉ layers.
pub fn sqrt_checkpoints(n_layers: usize) -> Vec<usize> {
    if n_layers < 4 {
        return Vec::new();
    }
    let step = (n_layers as f64).sqrt().ceil() as usize;
    (1..)
        .map(|i| i * step)
        .take_while(|&c| c < n_layers)
        .collect()
}

/// Checkpoint positions that keep every segment's *depth-driven* halo in
/// check while preferring pool boundaries (cheap to keep: smallest maps).
/// Used by the hybrids when the caller does not pin placements.
pub fn pool_boundary_checkpoints(net: &Network, max_segment_len: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut last = 0usize;
    for (i, l) in net.layers.iter().enumerate() {
        let pos = i + 1;
        if pos == net.layers.len() {
            break;
        }
        let due = pos - last >= max_segment_len;
        let at_pool = !l.is_conv();
        if at_pool || due {
            out.push(pos);
            last = pos;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{minivgg, vgg16};

    #[test]
    fn split_covers_whole_chain() {
        let net = vgg16();
        let segs = split_segments(&net, &[4, 9], 224, 224);
        assert_eq!(segs.len(), 3);
        assert_eq!(segs[0].l0, 0);
        assert_eq!(
            segs.iter().map(|s| s.layers.len()).sum::<usize>(),
            net.layers.len()
        );
        // heights chain: each segment's h_out is the next one's h_in
        assert_eq!(segs[0].h_out(), segs[1].h_in());
        assert_eq!(segs[1].h_out(), segs[2].h_in());
    }

    #[test]
    fn single_segment_when_no_checkpoints() {
        let net = minivgg();
        let segs = split_segments(&net, &[], 32, 32);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].h_in(), 32);
        assert_eq!(segs[0].h_out(), 8);
    }

    #[test]
    fn sqrt_spacing() {
        assert_eq!(sqrt_checkpoints(16), vec![4, 8, 12]);
        assert_eq!(sqrt_checkpoints(3), Vec::<usize>::new());
        let cks = sqrt_checkpoints(18); // VGG-16 chain
        assert!(!cks.is_empty());
        assert!(cks.iter().all(|&c| c < 18));
    }

    #[test]
    fn pool_boundaries_preferred() {
        let net = minivgg();
        let cks = pool_boundary_checkpoints(&net, 4);
        // pools are at layer indices 1 and 3 -> checkpoints after them
        assert_eq!(cks, vec![2, 4]);
    }
}
