//! Row-granularity solver — Eqs. (9)/(10)/(12)/(16).
//!
//! The paper's two principles (§III-C): the plan must fit the device
//! (peak + ξ < M), and N should be as *small* as possible to preserve
//! parallelism and bound coordination costs.  `solve_granularity` probes
//! N = 1, 2, … and returns the first feasible plan; infeasible geometries
//! (empty 2PS rows, OverL halo ≥ own share) are skipped, and the solver
//! can escalate to the hybrid variant when the flat plan never fits.

use crate::error::{Error, Result};
use crate::memory::{sim, DeviceModel};
use crate::model::Network;

use super::{checkpoint, RowCentric, RowMode, Strategy};

/// Result of a granularity search.
#[derive(Debug, Clone)]
pub struct GranularitySolution {
    pub plan: RowCentric,
    pub n: usize,
    pub peak_bytes: u64,
    pub xi: u64,
}

/// Find min N ≤ `n_max` such that the plan fits `dev`.  If `hybrid` is
/// true, checkpoints are placed at pool boundaries (max segment length
/// ⌈√L⌉) before searching — the -H variants.
pub fn solve_granularity(
    mode: RowMode,
    net: &Network,
    b: usize,
    h: usize,
    w: usize,
    dev: &DeviceModel,
    n_max: usize,
    hybrid: bool,
) -> Result<GranularitySolution> {
    let checkpoints = if hybrid {
        let seg_len = (net.layers.len() as f64).sqrt().ceil() as usize;
        checkpoint::pool_boundary_checkpoints(net, seg_len)
    } else {
        Vec::new()
    };
    let mut last_err: Option<Error> = None;
    for n in 1..=n_max {
        let plan = RowCentric {
            mode,
            n_rows: n,
            checkpoints: checkpoints.clone(),
        };
        let sched = match plan.schedule(net, b, h, w) {
            Ok(s) => s,
            Err(e @ Error::InfeasiblePlan(_)) => {
                // larger N in the same family will not become feasible for
                // 2PS (rows shrink), but OverL infeasibility is monotone in
                // N too — stop probing this family
                last_err = Some(e);
                break;
            }
            Err(e) => return Err(e),
        };
        let xi = plan.xi(net);
        match sim::check_fits(&sched, xi, dev.usable_hbm(), &plan.name()) {
            Ok(rep) => {
                return Ok(GranularitySolution {
                    n,
                    peak_bytes: rep.peak_bytes,
                    xi,
                    plan,
                })
            }
            Err(Error::OutOfMemory { .. }) => continue,
            Err(e) => return Err(e),
        }
    }
    Err(last_err.unwrap_or_else(|| Error::OutOfMemory {
        strategy: format!("{}{}", mode.label(), if hybrid { "-H" } else { "" }),
        required: 0,
        capacity: dev.usable_hbm(),
    }))
}

/// Largest batch size for which `solve` succeeds (the Fig. 6 probe).
/// Doubling ramp followed by binary search; probes `f(b) -> fits?`.
pub fn max_feasible(mut fits: impl FnMut(usize) -> bool, cap: usize) -> usize {
    if !fits(1) {
        return 0;
    }
    let mut lo = 1usize; // known-fits
    let mut hi = 2usize;
    while hi <= cap && fits(hi) {
        lo = hi;
        hi *= 2;
    }
    let mut hi = hi.min(cap + 1); // known-oom (or cap+1)
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vgg16;

    #[test]
    fn solver_prefers_small_n() {
        let net = vgg16();
        let dev = DeviceModel::rtx3090();
        let sol =
            solve_granularity(RowMode::Overlap, &net, 8, 224, 224, &dev, 32, true).unwrap();
        assert!(sol.n >= 1);
        // with B=8 at 224x224 even modest N must fit a 24 GB card
        assert!(sol.peak_bytes + sol.xi < dev.usable_hbm());
        // minimality: N-1 must not fit (or N == 1)
        if sol.n > 1 {
            let smaller = RowCentric {
                mode: RowMode::Overlap,
                n_rows: sol.n - 1,
                checkpoints: sol.plan.checkpoints.clone(),
            };
            let sched = smaller.schedule(&net, 8, 224, 224).unwrap();
            assert!(sim::check_fits(&sched, smaller.xi(&net), dev.usable_hbm(), "x").is_err());
        }
    }

    #[test]
    fn max_feasible_binary_search() {
        assert_eq!(max_feasible(|b| b <= 37, 1024), 37);
        assert_eq!(max_feasible(|b| b <= 1, 1024), 1);
        assert_eq!(max_feasible(|_| false, 1024), 0);
        assert_eq!(max_feasible(|b| b <= 2000, 1024), 1024);
    }
}
