//! Row-partitioning planners — the paper's §III/§IV contribution.
//!
//! A [`Strategy`] compiles one training iteration of a [`Network`] into
//! (a) an allocation [`Schedule`] for the memory simulator and (b)
//! [`CostCounters`] for the time model.  The row-centric strategies are:
//!
//! * [`RowCentric`] with [`RowMode::TwoPhase`] — 2PS (§IV-A): skewed rows
//!   planned by the backward height recursion, (k−s)-row caches shared
//!   between consecutive rows, coordination interruptions counted.
//! * [`RowCentric`] with [`RowMode::Overlap`] — OverL (§IV-B): even rows
//!   with replicated halos, redundant compute counted as ι.
//! * either mode with checkpoints — the hybrids 2PS-H / OverL-H: rows are
//!   planned *between* consecutive checkpoints, truncating the depth that
//!   inflates halos/caches (§IV-A "Impact of N", §IV-B OverL-H).
//!
//! Baselines (Base/Ckp/OffLoad/Tsplit) implement the same trait in
//! [`crate::baselines`].

pub mod analysis;
pub mod checkpoint;
pub mod granularity;
pub mod overlap;
pub mod twophase;

use crate::costmodel::CostCounters;
use crate::error::Result;
use crate::memory::{Schedule, Tracker};
use crate::model::{Network, F32_BYTES};

pub use checkpoint::{sqrt_checkpoints, SegmentView};
pub use granularity::{solve_granularity, GranularitySolution};

/// A memory-reduction strategy: everything the benches compare.
pub trait Strategy {
    fn name(&self) -> String;

    /// Compile one iteration into an allocation schedule.
    fn schedule(&self, net: &Network, b: usize, h: usize, w: usize) -> Result<Schedule>;

    /// Per-iteration cost counters for the time model.
    fn cost(&self, net: &Network, b: usize, h: usize, w: usize) -> Result<CostCounters>;

    /// Bytes of always-resident state (ξ): parameters + gradients (+
    /// optimizer state would go here too; plain SGD has none).
    fn xi(&self, net: &Network) -> u64 {
        2 * net.param_bytes()
    }
}

/// Which weak-dependency mechanism a row-centric plan uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowMode {
    /// 2PS — cache & share boundary rows between consecutive rows.
    TwoPhase,
    /// OverL — replicate halo rows; rows fully independent.
    Overlap,
}

impl RowMode {
    pub fn label(&self) -> &'static str {
        match self {
            RowMode::TwoPhase => "2PS",
            RowMode::Overlap => "OverL",
        }
    }
}

/// A concrete row-centric plan: mode + rows-per-segment + checkpoints.
#[derive(Debug, Clone)]
pub struct RowCentric {
    pub mode: RowMode,
    /// rows per segment (N = N_BP, paper §III-C)
    pub n_rows: usize,
    /// checkpoint positions (indices into `net.layers`, exclusive
    /// boundaries); empty = single segment over the whole conv chain
    pub checkpoints: Vec<usize>,
}

impl RowCentric {
    pub fn new(mode: RowMode, n_rows: usize) -> Self {
        RowCentric {
            mode,
            n_rows,
            checkpoints: Vec::new(),
        }
    }

    pub fn hybrid(mode: RowMode, n_rows: usize, checkpoints: Vec<usize>) -> Self {
        RowCentric {
            mode,
            n_rows,
            checkpoints,
        }
    }

    pub fn is_hybrid(&self) -> bool {
        !self.checkpoints.is_empty()
    }

    /// Split the network into segments at the checkpoints.
    ///
    /// The *flat* variants (no checkpoints) row-partition only the longest
    /// layer **prefix** on which the target N is still effective/feasible,
    /// leaving the remainder column-centric — this is what the paper's
    /// Table I reports for plain OverL/2PS (e.g. only 6 of VGG-16's 18
    /// layers are involved): the early high-resolution layers dominate ρ^l,
    /// and partitioning deeper layers without checkpoints lets halos/caches
    /// blow up (§IV-A/§IV-B "Impact of N").
    pub fn segments<'n>(&self, net: &'n Network, h: usize, w: usize) -> Vec<SegmentView<'n>> {
        if !self.checkpoints.is_empty() {
            return checkpoint::split_segments(net, &self.checkpoints, h, w);
        }
        let l = net.layers.len();
        let d = self.flat_prefix_len(net, h, w);
        if d == 0 || d >= l {
            checkpoint::split_segments(net, &[], h, w)
        } else {
            checkpoint::split_segments(net, &[d], h, w)
        }
    }

    /// Longest prefix depth on which `n_rows` is effective for this mode.
    fn flat_prefix_len(&self, net: &Network, h: usize, w: usize) -> usize {
        let hs = net.heights(h);
        let ws = net.widths(w);
        let mut best = 0usize;
        for d in 1..=net.layers.len() {
            let seg = SegmentView {
                l0: 0,
                layers: &net.layers[0..d],
                heights: hs[0..=d].to_vec(),
                widths: ws[0..=d].to_vec(),
            };
            let ok = match self.mode {
                RowMode::TwoPhase => {
                    let want = self.n_rows.min(seg.h_out()).max(1);
                    want >= 2 && twophase::max_feasible_n(&seg, self.n_rows) >= want
                }
                RowMode::Overlap => overlap::prefix_effective(&seg, self.n_rows),
            };
            if ok {
                best = d;
            }
        }
        best
    }

    /// Per-segment row targets: hybrids partition every segment; flat
    /// plans partition only the auto-selected prefix (segment 0) and keep
    /// the tail column-centric (paper Table I: plain variants involve only
    /// a subset of layers).
    pub fn segment_targets(&self, n_segments: usize) -> Vec<usize> {
        (0..n_segments)
            .map(|i| {
                if self.is_hybrid() || i == 0 {
                    self.n_rows
                } else {
                    1
                }
            })
            .collect()
    }

    /// Effective rows per segment after the feasibility degradation the
    /// paper's §IV analysis mandates (2PS: no empty own-rows; OverL: at
    /// least one non-replicated row).
    pub fn effective_rows(&self, net: &Network, h: usize, w: usize) -> Vec<usize> {
        let segs = self.segments(net, h, w);
        let targets = self.segment_targets(segs.len());
        segs.iter()
            .zip(targets)
            .map(|(seg, t)| match self.mode {
                RowMode::TwoPhase => twophase::max_feasible_n(seg, t),
                RowMode::Overlap => overlap::max_effective_n(seg, t),
            })
            .collect()
    }

    /// Table-I metrics: (#layers involved in row-centric update, Σ rows).
    ///
    /// A segment's layers count as row-centric when the segment is actually
    /// partitioned (effective N ≥ 2); each conv layer contributes N rows.
    pub fn table1_metrics(&self, net: &Network, h: usize, w: usize) -> (usize, usize) {
        let mut layers = 0usize;
        let mut rows = 0usize;
        for (seg, n) in self
            .segments(net, h, w)
            .iter()
            .zip(self.effective_rows(net, h, w))
        {
            if n >= 2 {
                layers += seg.layers.len();
                rows += n * seg.layers.iter().filter(|l| l.is_conv()).count();
            }
        }
        (layers, rows)
    }
}

impl Strategy for RowCentric {
    fn name(&self) -> String {
        let base = self.mode.label();
        if self.is_hybrid() {
            format!("{base}-H(N={})", self.n_rows)
        } else {
            format!("{base}(N={})", self.n_rows)
        }
    }

    fn schedule(&self, net: &Network, b: usize, h: usize, w: usize) -> Result<Schedule> {
        match self.mode {
            RowMode::TwoPhase => twophase::schedule(self, net, b, h, w),
            RowMode::Overlap => overlap::schedule(self, net, b, h, w),
        }
    }

    fn cost(&self, net: &Network, b: usize, h: usize, w: usize) -> Result<CostCounters> {
        match self.mode {
            RowMode::TwoPhase => twophase::cost(self, net, b, h, w),
            RowMode::Overlap => overlap::cost(self, net, b, h, w),
        }
    }
}

/// Bytes of a feature-map slab: `b · c · rows · w`.
pub(crate) fn slab_bytes(b: usize, c: usize, rows: usize, w: usize) -> u64 {
    (b * c * rows * w) as u64 * F32_BYTES
}

/// Shared helper: schedule the always-held input batch + final z^L + FC
/// head window around a body closure.  Used by every row-centric schedule.
pub(crate) fn with_iteration_frame(
    net: &Network,
    b: usize,
    h: usize,
    w: usize,
    body: impl FnOnce(&mut Schedule) -> Result<()>,
) -> Result<Schedule> {
    let mut s = Schedule::new();
    s.mark("input");
    s.alloc("input", slab_bytes(b, net.c_in, h, w));
    body(&mut s)?;
    s.free("input");
    Ok(s)
}

/// Validate a live tracker's peak against a simulated schedule's peak.
/// (Used in tests; exposed for the examples' reporting.)
pub fn validate_tracker(sim_peak: u64, tracker: &Tracker, tolerance_frac: f64) -> bool {
    let live = tracker.peak() as f64;
    let sim = sim_peak as f64;
    (live - sim).abs() <= sim * tolerance_frac
}
