//! Closed-form space-complexity analysis — the paper's Eqs. (3), (7), (8),
//! and the Eq. (12)/(16) bound terms — computed symbolically from the layer
//! graph and cross-checked against the event-level simulator in tests.
//!
//! This is the "accompanied analysis [that] can help to gain optimal
//! performance … while insulating end-users from tedious low-level
//! details" (paper contribution #2): a user can ask *why* a plan has the
//! peak it has without replaying a schedule.

use crate::model::{Network, F32_BYTES};
use crate::shapes;

/// Eq. (3): Ω — column-centric accumulated feature bytes.
pub fn omega_column(net: &Network, b: usize, h: usize, w: usize) -> u64 {
    net.total_feature_bytes(b, h, w)
}

/// Eq. (7): Ω_FP(N) = max_{l<L} ρ^l/N + ρ^L  (single segment, even rows).
pub fn omega_fp(net: &Network, b: usize, h: usize, w: usize, n: usize) -> u64 {
    let fb = net.feature_bytes(b, h, w);
    let inner_max = fb[1..fb.len() - 1].iter().copied().max().unwrap_or(0);
    inner_max / n as u64 + *fb.last().unwrap()
}

/// Eq. (8): Ω_BP(N) = Σ_{l<L} ρ^l/N + ρ^L.
pub fn omega_bp(net: &Network, b: usize, h: usize, w: usize, n: usize) -> u64 {
    let fb = net.feature_bytes(b, h, w);
    let inner_sum: u64 = fb[1..fb.len() - 1].iter().sum();
    inner_sum / n as u64 + *fb.last().unwrap()
}

/// Eq. (12)'s sharing term: B·(N−1)·Σ_l (k^l − s^l)·W^l·C^l bytes — the
/// resident 2PS cache volume.
pub fn tps_sharing_bytes(net: &Network, b: usize, w: usize, n: usize) -> u64 {
    let ws = net.widths(w);
    let per_row: u64 = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| (l.k.saturating_sub(l.s) * ws[i] * l.c_in) as u64)
        .sum();
    b as u64 * (n as u64 - 1) * per_row * F32_BYTES
}

/// Eq. (15)/(16)'s overlap term: B·(N−1)·Σ_l o^l·W^l·C^l bytes of
/// replicated data for an even partition of the full chain.
pub fn overl_overlap_bytes(net: &Network, b: usize, h: usize, w: usize, n: usize) -> u64 {
    if n < 2 {
        return 0;
    }
    let hs = net.heights(h);
    let ws = net.widths(w);
    let h_out = *hs.last().unwrap();
    if n > h_out {
        return u64::MAX; // infeasible regime (N > H/o_r)
    }
    let ivs = shapes::even_partition(h_out, n);
    let mut total = 0u64;
    for r in 0..n - 1 {
        let a = shapes::slab_chain(&net.layers, &hs, ivs[r]);
        let bb = shapes::slab_chain(&net.layers, &hs, ivs[r + 1]);
        // input-level overlap
        let ov0 = a[0].in_iv.1.saturating_sub(bb[0].in_iv.0);
        total += (b * net.c_in * ov0 * ws[0]) as u64;
        for (i, l) in net.layers.iter().enumerate() {
            let ov = a[i].out_iv.1.saturating_sub(bb[i].out_iv.0);
            total += (b * l.c_out * ov * ws[i + 1]) as u64;
        }
    }
    total * F32_BYTES
}

/// Paper §III-C: N = N_BP because Ω_BP(N) ≥ Ω_FP(N) for every N.
pub fn bp_dominates_fp(net: &Network, b: usize, h: usize, w: usize, n_max: usize) -> bool {
    (1..=n_max).all(|n| omega_bp(net, b, h, w, n) >= omega_fp(net, b, h, w, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Base;
    use crate::memory::sim;
    use crate::model::{minivgg, vgg16};
    use crate::planner::{RowCentric, RowMode, Strategy};

    #[test]
    fn eq3_matches_simulated_base_peak() {
        // the simulator's Base peak must bracket Ω (it adds input + δ pair)
        for net in [vgg16(), minivgg()] {
            let (b, h, w) = (8, net.h, net.w);
            let omega = omega_column(&net, b, h, w);
            let peak = sim::simulate(&Base.schedule(&net, b, h, w).unwrap())
                .unwrap()
                .peak_bytes;
            assert!(peak >= omega, "{}: peak {peak} < Ω {omega}", net.name);
            assert!(
                peak < omega + omega / 2,
                "{}: peak {peak} should stay within 1.5Ω",
                net.name
            );
        }
    }

    #[test]
    fn eq7_eq8_monotone_and_bp_dominates() {
        let net = vgg16();
        let (b, h, w) = (16, 224, 224);
        assert!(bp_dominates_fp(&net, b, h, w, 16));
        let mut prev = u64::MAX;
        for n in 1..=16 {
            let o = omega_bp(&net, b, h, w, n);
            assert!(o <= prev, "Ω_BP must shrink with N");
            prev = o;
        }
        // Ω_BP(1) + input ≈ Base
        let base = omega_column(&net, b, h, w);
        assert!(omega_bp(&net, b, h, w, 1) <= base + base / 10);
    }

    #[test]
    fn eq12_sharing_matches_cost_counter() {
        // the planner's SD counter must approximate the closed form for a
        // single-segment plan (flat prefix ⇒ compare on the prefix only)
        let net = minivgg();
        let rc = RowCentric::new(RowMode::TwoPhase, 2);
        let c = rc.cost(&net, 8, 32, 32).unwrap();
        let closed = tps_sharing_bytes(&net, 8, 32, 2);
        // the flat plan covers a prefix, so measured SD ≤ closed form over
        // the full chain, and both are the same order
        assert!(c.sharing_bytes <= closed);
        assert!(c.sharing_bytes * 4 >= closed / 4, "{} vs {closed}", c.sharing_bytes);
    }

    #[test]
    fn eq16_overlap_grows_superlinearly_near_infeasibility() {
        let net = minivgg(); // h_out = 8
        let o2 = overl_overlap_bytes(&net, 8, 32, 32, 2);
        let o4 = overl_overlap_bytes(&net, 8, 32, 32, 4);
        let o8 = overl_overlap_bytes(&net, 8, 32, 32, 8);
        assert!(o2 < o4 && o4 < o8, "{o2} {o4} {o8}");
        // near N = H^L the marginal overlap per extra row keeps growing
        assert!(o8 - o4 > o4 - o2);
        assert_eq!(overl_overlap_bytes(&net, 8, 32, 32, 9), u64::MAX);
    }

    #[test]
    fn row_centric_sim_peak_respects_eq8_scaling() {
        // OverL-H at N vs N=1: the simulated peak reduction should land in
        // the band the closed forms predict (between Ω_BP(N)+overlap and Ω)
        let net = vgg16();
        let (b, h, w) = (16, 224, 224);
        let cks = crate::planner::checkpoint::pool_boundary_checkpoints(&net, 5);
        let rc1 = RowCentric::hybrid(RowMode::Overlap, 1, cks.clone());
        let rc8 = RowCentric::hybrid(RowMode::Overlap, 8, cks);
        let p1 = sim::simulate(&rc1.schedule(&net, b, h, w).unwrap()).unwrap().peak_bytes;
        let p8 = sim::simulate(&rc8.schedule(&net, b, h, w).unwrap()).unwrap().peak_bytes;
        let predicted_floor = omega_bp(&net, b, h, w, 8);
        assert!(p8 < p1, "partitioning must reduce the peak");
        assert!(
            p8 >= predicted_floor / 4,
            "simulated {p8} implausibly below the Eq. 8 floor {predicted_floor}"
        );
    }
}
