//! Convolution shape arithmetic and the row-interval (halo) calculus.
//!
//! This is the Rust mirror of `python/compile/rowplan.py` — the generalized
//! form of the paper's Eq. (11)/(13)/(14)/(15) height recursions.  Both
//! sides are cross-checked against the AOT manifest in integration tests
//! (`rust/tests/manifest_crosscheck.rs`).

pub mod interval;

pub use interval::{
    back_interval, even_partition, fwd_interval, overlap_rows, slab_chain, tps_boundaries,
    tps_cache_rows, Interval, SlabChain, SlabLayer,
};

/// Output spatial size of a k/s/p window over `n` input positions.
pub fn conv_out(n: usize, k: usize, s: usize, p: usize) -> usize {
    assert!(
        n + 2 * p >= k,
        "window {k} larger than padded input {n}+2*{p}"
    );
    (n + 2 * p - k) / s + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_out_same_and_valid() {
        assert_eq!(conv_out(32, 3, 1, 1), 32); // SAME 3x3
        assert_eq!(conv_out(32, 3, 1, 0), 30); // VALID 3x3
        assert_eq!(conv_out(32, 2, 2, 0), 16); // pool 2/2
        assert_eq!(conv_out(224, 7, 2, 3), 112); // ResNet stem
    }

    #[test]
    #[should_panic]
    fn conv_out_too_small() {
        conv_out(1, 3, 1, 0);
    }
}
