//! Exact row-interval (halo) calculus — the generalized Eq. (11)–(15).
//!
//! Output rows `[a, b)` of a k/s/p layer need input rows
//! `[a·s − p, (b−1)·s − p + k) ∩ [0, H_in)`, with the clipped amount
//! re-introduced as padding **only at true image boundaries** — the paper's
//! semi-closed padding (§III-B).  Because this backward map is the exact
//! preimage, re-running a slab forward reproduces exactly the target
//! interval at every layer; row-concatenation is bit-equal to the
//! column-centric result.

use crate::model::Layer;

/// Half-open row interval `[start, end)`.
pub type Interval = (usize, usize);

/// Per-layer slab geometry for one row's forward pass.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlabLayer {
    /// rows of the layer *input* held by the slab
    pub in_iv: Interval,
    /// rows of the layer *output* the slab produces
    pub out_iv: Interval,
    /// semi-closed padding actually applied (non-zero at true edges only)
    pub pad_top: usize,
    pub pad_bottom: usize,
}

/// Full slab chain of one row through a layer stack (input layer first).
pub type SlabChain = Vec<SlabLayer>;

/// Exact preimage of output rows `out_iv` through `layer` with input height
/// `h_in`.  Returns (input interval, pad_top, pad_bottom).
pub fn back_interval(layer: &Layer, out_iv: Interval, h_in: usize) -> (Interval, usize, usize) {
    let (a, b) = out_iv;
    assert!(a < b, "empty interval {out_iv:?}");
    let start_u = a as i64 * layer.s as i64 - layer.p as i64;
    let end_u = (b as i64 - 1) * layer.s as i64 - layer.p as i64 + layer.k as i64;
    let ia = start_u.max(0) as usize;
    let ib = (end_u.min(h_in as i64)) as usize;
    let pad_top = (ia as i64 - start_u) as usize;
    let pad_bottom = (end_u - ib as i64) as usize;
    debug_assert!(pad_top <= layer.p && pad_bottom <= layer.p);
    ((ia, ib), pad_top, pad_bottom)
}

/// Output rows produced by a slab covering `in_iv` with the given pads.
pub fn fwd_interval(layer: &Layer, in_iv: Interval, pad_top: usize, pad_bottom: usize) -> Interval {
    let (ia, ib) = in_iv;
    let lo = ia as i64 - pad_top as i64;
    let hi = ib as i64 + pad_bottom as i64;
    let s = layer.s as i64;
    let o_start = (lo + layer.p as i64 + s - 1).div_euclid(s); // ceil
    let o_end = (hi + layer.p as i64 - layer.k as i64).div_euclid(s) + 1;
    (o_start.max(0) as usize, o_end.max(0) as usize)
}

/// Build the slab chain producing `out_iv` at the end of `layers`, whose
/// per-layer input heights are `heights[0..layers.len()]`.
///
/// Panics (debug assert in release: returns garbage-free chain) if the
/// forward replay does not reproduce the backward intervals — that would
/// mean the calculus itself is broken, not the caller.
pub fn slab_chain(layers: &[Layer], heights: &[usize], out_iv: Interval) -> SlabChain {
    assert_eq!(heights.len(), layers.len() + 1);
    // walk backward collecting required input intervals
    let mut ivs: Vec<(Interval, usize, usize)> = vec![(out_iv, 0, 0)];
    let mut iv = out_iv;
    for idx in (0..layers.len()).rev() {
        let (niv, pt, pb) = back_interval(&layers[idx], iv, heights[idx]);
        ivs.push((niv, pt, pb));
        iv = niv;
    }
    ivs.reverse(); // ivs[i] = (interval at layer-i input, pads of layer i)
    let mut chain = SlabChain::with_capacity(layers.len());
    for (idx, layer) in layers.iter().enumerate() {
        let (in_iv, pt, pb) = ivs[idx];
        let produced = fwd_interval(layer, in_iv, pt, pb);
        let expected = ivs[idx + 1].0;
        assert_eq!(
            produced, expected,
            "interval calculus broke at layer {idx}: {produced:?} != {expected:?}"
        );
        chain.push(SlabLayer {
            in_iv,
            out_iv: produced,
            pad_top: pt,
            pad_bottom: pb,
        });
    }
    chain
}

/// Even division of `h` rows into `n` intervals (paper §IV-B: divide the
/// last layer evenly, deconvolve to size the input slabs).
pub fn even_partition(h: usize, n: usize) -> Vec<Interval> {
    assert!(n >= 1 && n <= h, "N={n} rows over H={h}");
    let cuts: Vec<usize> = (0..=n).map(|i| (i * h + n / 2) / n).collect();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        assert!(cuts[i] < cuts[i + 1], "empty row {i} in partition of {h} by {n}");
        out.push((cuts[i], cuts[i + 1]));
    }
    out
}

/// Overlap (replicated input rows) between adjacent slabs — Eq. (15)'s
/// o_r^0, computed exactly instead of by the closed-form recursion.
pub fn overlap_rows(layers: &[Layer], heights: &[usize], ivs: &[Interval]) -> Vec<usize> {
    let mut out = Vec::new();
    for r in 0..ivs.len().saturating_sub(1) {
        let a = slab_chain(layers, heights, ivs[r])[0].in_iv;
        let b = slab_chain(layers, heights, ivs[r + 1])[0].in_iv;
        out.push(a.1.saturating_sub(b.0));
    }
    out
}

/// 2PS ownership boundaries per layer input, top-down (Eq. (11)/(13)/(14)).
///
/// `out_cuts` are the boundaries at the segment output (e.g. `[0, 4, 8]`).
/// Returns `bounds[layer_input_idx][cut_idx]`.
pub fn tps_boundaries(layers: &[Layer], heights: &[usize], out_cuts: &[usize]) -> Vec<Vec<usize>> {
    assert_eq!(heights.len(), layers.len() + 1);
    assert_eq!(out_cuts[0], 0);
    assert_eq!(*out_cuts.last().unwrap(), *heights.last().unwrap());
    let mut bounds = vec![out_cuts.to_vec()];
    let mut cuts = out_cuts.to_vec();
    for idx in (0..layers.len()).rev() {
        let l = &layers[idx];
        let h_in = heights[idx];
        cuts = cuts
            .iter()
            .map(|&c| {
                if c == 0 {
                    0
                } else {
                    h_in.min(((c - 1) * l.s + l.k).saturating_sub(l.p))
                }
            })
            .collect();
        bounds.push(cuts.clone());
    }
    bounds.reverse();
    bounds
}

/// Rows of each layer input that 2PS row `r` reuses from row r−1's cache:
/// `[needed_start, own_start)` — (k − s) rows interior, 0 for pools.
pub fn tps_cache_rows(
    layers: &[Layer],
    bounds: &[Vec<usize>],
    r: usize,
) -> Vec<Option<(usize, usize)>> {
    assert!(r >= 1);
    layers
        .iter()
        .enumerate()
        .map(|(idx, l)| {
            let own_start = bounds[idx][r];
            let out_start = bounds[idx + 1][r];
            let needed = (out_start * l.s).saturating_sub(l.p);
            if needed < own_start {
                Some((needed, own_start))
            } else {
                None
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Layer;

    fn conv3() -> Layer {
        Layer::conv(8, 8, 3, 1, 1)
    }

    fn pool2() -> Layer {
        Layer::pool(8, 2)
    }

    #[test]
    fn back_interval_interior_and_edges() {
        let l = conv3();
        // interior: [2,4) of a 3x3 SAME conv needs [1,5), no padding
        assert_eq!(back_interval(&l, (2, 4), 8), ((1, 5), 0, 0));
        // top edge: [0,2) needs [0,3) + 1 row of padding at the top
        assert_eq!(back_interval(&l, (0, 2), 8), ((0, 3), 1, 0));
        // bottom edge
        assert_eq!(back_interval(&l, (6, 8), 8), ((5, 8), 0, 1));
        // pool: no dependency across row boundary
        assert_eq!(back_interval(&pool2(), (1, 3), 8), ((2, 6), 0, 0));
    }

    #[test]
    fn fwd_is_exact_inverse_of_back() {
        for layer in [conv3(), pool2(), Layer::conv(8, 8, 7, 2, 3), Layer::conv(8, 8, 1, 1, 0)] {
            let h_in = 64;
            let h_out = crate::shapes::conv_out(h_in, layer.k, layer.s, layer.p);
            for a in 0..h_out {
                for b in (a + 1)..=h_out {
                    let (iv, pt, pb) = back_interval(&layer, (a, b), h_in);
                    assert_eq!(
                        fwd_interval(&layer, iv, pt, pb),
                        (a, b),
                        "layer {layer:?} iv ({a},{b})"
                    );
                }
            }
        }
    }

    #[test]
    fn slab_chain_minivgg_matches_known_geometry() {
        // segment A of the live MiniVGG plan: conv-pool-conv-pool over H=32.
        let layers = vec![
            Layer::conv(3, 16, 3, 1, 1),
            Layer::pool(16, 2),
            Layer::conv(16, 32, 3, 1, 1),
            Layer::pool(32, 2),
        ];
        let heights = vec![32, 32, 16, 16, 8];
        // values cross-checked against python rowplan (and the manifest)
        let chain = slab_chain(&layers, &heights, (0, 2));
        assert_eq!(chain[0].in_iv, (0, 11));
        let chain = slab_chain(&layers, &heights, (2, 4));
        assert_eq!(chain[0].in_iv, (5, 19));
        let chain = slab_chain(&layers, &heights, (6, 8));
        assert_eq!(chain[0].in_iv, (21, 32));
        assert_eq!(chain.last().unwrap().out_iv, (6, 8));
    }

    #[test]
    fn even_partition_covers_and_is_monotone() {
        for h in [7usize, 8, 13, 224] {
            for n in 1..=h.min(14) {
                let ivs = even_partition(h, n);
                assert_eq!(ivs[0].0, 0);
                assert_eq!(ivs.last().unwrap().1, h);
                for w in ivs.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
            }
        }
    }

    #[test]
    fn tps_boundaries_match_paper_recursion() {
        // full-depth MiniVGG, N=2, cuts at the conv4 output [0,4,8]:
        // the backward recursion must give own-intervals [0,27) / [27,32)
        // at the input (cross-checked with the AOT manifest).
        let layers = vec![
            Layer::conv(3, 16, 3, 1, 1),
            Layer::pool(16, 2),
            Layer::conv(16, 32, 3, 1, 1),
            Layer::pool(32, 2),
            Layer::conv(32, 64, 3, 1, 1),
            Layer::conv(64, 64, 3, 1, 1),
        ];
        let heights = vec![32, 32, 16, 16, 8, 8, 8];
        let bounds = tps_boundaries(&layers, &heights, &[0, 4, 8]);
        assert_eq!(bounds[0], vec![0, 27, 32]);
        // cache sizes are k - s = 2 rows at interior conv layers, none at pools
        let caches = tps_cache_rows(&layers, &bounds, 1);
        assert_eq!(caches[0], Some((25, 27)));
        assert_eq!(caches[1], None); // pool
        assert_eq!(caches[2], Some((11, 13)));
        assert_eq!(caches[3], None); // pool
        assert_eq!(caches[4], Some((4, 6)));
        assert_eq!(caches[5], Some((3, 5)));
    }

    #[test]
    fn overlap_grows_with_depth() {
        let mk = |n_conv: usize| -> (Vec<Layer>, Vec<usize>) {
            let layers: Vec<Layer> = (0..n_conv).map(|_| conv3()).collect();
            let heights = vec![64; n_conv + 1];
            (layers, heights)
        };
        let (l1, h1) = mk(2);
        let (l2, h2) = mk(6);
        let ivs = even_partition(64, 4);
        let o_small = overlap_rows(&l1, &h1, &ivs)[1];
        let o_big = overlap_rows(&l2, &h2, &ivs)[1];
        assert!(o_big > o_small, "{o_big} vs {o_small}");
        // k=3,s=1 stack: halo is exactly `depth` rows each side → 2*depth shared
        assert_eq!(o_small, 4);
        assert_eq!(o_big, 12);
    }
}
