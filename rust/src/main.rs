//! lr-cnn — CLI launcher for the LR-CNN reproduction.
//!
//! Subcommands (argument parsing is hand-rolled; clap is unavailable in the
//! offline build environment — DESIGN.md §2):
//!
//!   plan   --net vgg16|resnet50 --device rtx3090|rtx3080 --batch B \
//!          [--dim H] [--rows N]
//!          — memory-plan an iteration and print peak/fit per strategy
//!   plan   --dump-ir [--optimized] [--artifacts DIR] [--out FILE]
//!          — lower the row-program IR for all 4 modes (artifact bundle's
//!          manifest when given, the built-in demo bundle otherwise),
//!          validate() each program and emit the node/task/deps/bytes
//!          JSON (docs/ROWIR.md); --optimized additionally runs the
//!          rowir::opt fixpoint pipeline at level 2 and emits the
//!          post-opt program + pass report side by side with the
//!          pristine one; nonzero exit on any lowering regression
//!   plan   --optimize [--opt-level 0|1|2] [--artifacts DIR]
//!          — run the optimizer pipeline over every mode's lowered
//!          program and print the before/after static-peak table
//!          (docs/ROWIR.md "Optimizer")
//!   plan   --lint [--devices N] [--artifacts DIR] [--lint-out FILE]
//!          — run the static-analysis suite (docs/ANALYSIS.md: structure,
//!          determinism lint, liveness, shard race/transfer checker) over
//!          every mode's lowered program, serially and sharded over N
//!          devices (default 2) under all three partition policies;
//!          renders diagnostics as tables, --lint-out writes the
//!          machine-readable JSON report, nonzero exit on any error
//!          diagnostic
//!   train  --mode base|overl-h|2ps|naive [--steps N] [--lr F] [--artifacts DIR]
//!          [--demo] [--workers N] [--devices N] [--device-spec SPEC]
//!          [--policy blocked|balanced|dp] [--link pcie|nvlink]
//!          [--fault-plan SPEC] [--retry N[:BACKOFF_US]]
//!          [--on-device-lost fail|degrade] [--trace-out FILE]
//!          [--report-out FILE] [--perfetto-out FILE] [--flight-out FILE]
//!          [--recalibrate-every N]
//!          — live training on the PJRT artifacts (MiniVGG, synthetic data);
//!          --workers enables the pipelined scheduler, --devices shards the
//!          row DAG over N identical RTX 3090s, --device-spec over an
//!          explicit (mixed) topology like `rtx3090:2,a100:2` (entries are
//!          name[@hbm-percent][:count]), --trace-out dumps the last step's
//!          per-device trace JSON.  --fault-plan injects deterministic
//!          faults on the sharded path (`s<step>.<target>=<kind>[*times]`
//!          grammar or `random:SEED[:COUNT]` — docs/RESILIENCE.md),
//!          --retry bounds transient-fault redispatches, --on-device-lost
//!          picks between failing the step and degrading onto survivors.
//!          --demo runs the offline deterministic backend (no artifact
//!          bundle needed); --report-out records timed spans and writes the
//!          versioned RunReport JSON (cost model calibrated over the run —
//!          docs/OBSERVABILITY.md); --perfetto-out writes the unified
//!          Perfetto/Chrome trace (execution lanes + counters + markers);
//!          --flight-out writes the flight recorder's bounded crash
//!          report — on a failed run it captures the failing dispatch,
//!          on success the last spans on demand; --recalibrate-every N
//!          arms the online loop (refit the cost model every N steps and
//!          repartition under drift, guarded never-slower);
//!          --lint-strict refuses to train unless the active plan's
//!          static-analysis report is fully clean — warnings included
//!          (docs/ANALYSIS.md); --opt-level 0|1|2 runs the rowir::opt
//!          fixpoint pipeline over the lowered program (and, sharded,
//!          over the transfer-lowered plan) before training — level 1
//!          is dce + transfer coalescing, level 2 adds budget-driven
//!          rematerialization (docs/ROWIR.md "Optimizer")
//!   info   [--artifacts DIR]
//!          — print the artifact bundle inventory
//!   trace  --net vgg16 --strategy overl-h [--batch B] [--rows N] [--out FILE]
//!          — export a plan's memory profile as Chrome trace JSON
//!   report --in FILE
//!          — render a `train --report-out` JSON as tables
//!
//! Exit codes: 0 success; 2 usage/config; 3 infeasible plan or
//! out-of-memory; 4 device lost (unrecoverable); 5 transient-retry
//! exhaustion; 1 anything else.

use lr_cnn::baselines::{Base, Ckp, OffLoad, Tsplit};
use lr_cnn::coordinator::{trainer::train_loop, Mode, Trainer};
use lr_cnn::data::SyntheticCorpus;
use lr_cnn::error::Error;
use lr_cnn::faults::{DeviceLostPolicy, FaultConfig, FaultPlan};
use lr_cnn::memory::{sim, DeviceModel};
use lr_cnn::metrics::{fmt_bytes, Table};
use lr_cnn::model::{resnet50, vgg16, Network};
use lr_cnn::planner::{RowCentric, RowMode, Strategy};
use lr_cnn::runtime::Runtime;
use lr_cnn::sched::{RetryPolicy, SchedConfig};
use lr_cnn::shard::{DeviceSpec, LinkKind, PartitionPolicy, ShardConfig};

use std::collections::HashMap;
use std::process::ExitCode;

/// CLI failure classes, mapped to distinct exit codes in [`main`] so
/// scripts (and the CI fault matrix) can tell a bad flag from an
/// infeasible plan from a lost device without scraping stderr.
enum CliError {
    /// Bad flags or configuration — exit 2.
    Usage(String),
    /// A typed library error — exit code by class ([`error_code`]).
    Run(Error),
    /// Anything else (IO, …) — exit 1.
    Other(String),
}

impl From<String> for CliError {
    fn from(msg: String) -> CliError {
        CliError::Usage(msg)
    }
}

impl From<&str> for CliError {
    fn from(msg: &str) -> CliError {
        CliError::Usage(msg.to_string())
    }
}

/// Exit code for a typed library error: 3 = the plan/step cannot fit
/// (infeasible partition or memory), 4 = a device was lost and the run
/// could not (or was told not to) degrade, 5 = a transient fault
/// outlived its retry budget, 2 = configuration, 1 = everything else.
fn error_code(e: &Error) -> u8 {
    match e {
        Error::InfeasiblePlan(_) | Error::OutOfMemory { .. } | Error::Memory(_) => 3,
        Error::DeviceLost { .. } => 4,
        Error::Retryable { .. } => 5,
        Error::Config(_) => 2,
        _ => 1,
    }
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            // a flag followed by another flag (or nothing) is boolean —
            // present with an empty value (e.g. `--dump-ir --artifacts D`)
            match args.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    map.insert(key.to_string(), v.clone());
                    i += 2;
                }
                _ => {
                    map.insert(key.to_string(), String::new());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    map
}

fn net_by_name(name: &str) -> Option<Network> {
    match name {
        "vgg16" => Some(vgg16()),
        "vgg19" => Some(lr_cnn::model::vgg19()),
        "resnet50" => Some(resnet50()),
        "resnet18" => Some(lr_cnn::model::resnet18()),
        "alexnet" => Some(lr_cnn::model::alexnet()),
        "minivgg" => Some(lr_cnn::model::minivgg()),
        _ => None,
    }
}

fn device_by_name(name: &str) -> Option<DeviceModel> {
    match name {
        "rtx3090" => Some(DeviceModel::rtx3090()),
        "rtx3080" => Some(DeviceModel::rtx3080()),
        "a100" => Some(DeviceModel::a100_80g()),
        _ => None,
    }
}

fn strategies(net: &Network, dev: &DeviceModel, n_rows: usize) -> Vec<Box<dyn Strategy>> {
    let cks = lr_cnn::planner::checkpoint::pool_boundary_checkpoints(
        net,
        (net.layers.len() as f64).sqrt().ceil() as usize,
    );
    vec![
        Box::new(Base),
        Box::new(Ckp::auto(net)),
        Box::new(OffLoad::full(dev)),
        Box::new(Tsplit::auto(dev)),
        Box::new(RowCentric::new(RowMode::TwoPhase, n_rows)),
        Box::new(RowCentric::new(RowMode::Overlap, n_rows)),
        Box::new(RowCentric::hybrid(RowMode::TwoPhase, n_rows, cks.clone())),
        Box::new(RowCentric::hybrid(RowMode::Overlap, n_rows, cks)),
    ]
}

/// `plan --dump-ir`: lower + validate the row program for every mode and
/// emit the IR as JSON — the CI smoke that catches lowering regressions
/// without needing artifacts (the built-in demo bundle stands in).
fn cmd_dump_ir(flags: &HashMap<String, String>) -> Result<(), String> {
    use lr_cnn::rowir::{self, Mode};
    use lr_cnn::runtime::Manifest;
    let man = match flags.get("artifacts").filter(|d| !d.is_empty()) {
        Some(dir) => Manifest::load(std::path::Path::new(dir)).map_err(|e| e.to_string())?,
        None => {
            eprintln!("plan --dump-ir: no --artifacts given, lowering the built-in demo bundle");
            Manifest::demo(2)
        }
    };
    let optimized = flags.contains_key("optimized");
    let mut out = String::from("[\n");
    for (i, mode) in Mode::ALL.iter().enumerate() {
        match rowir::lower(&man, *mode) {
            Ok(program) => {
                // `lower` validated already; re-check the boundary anyway —
                // this is the regression tripwire CI runs
                program
                    .validate()
                    .map_err(|e| format!("{} IR invalid: {e}", mode.label()))?;
                // --optimized: the post-opt program + pass report ride
                // along beside the pristine dump, so a diff of the two
                // `program` objects is exactly what the optimizer did
                let opt_field = if optimized {
                    let (optp, rep) =
                        rowir::optimize(&program, 2, &rowir::OptContext::serial())
                            .map_err(|e| format!("{} optimize: {e}", mode.label()))?;
                    optp.validate()
                        .map_err(|e| format!("{} post-opt IR invalid: {e}", mode.label()))?;
                    format!(
                        ", \"optimized\": {{\"len\": {}, \"report\": {}, \"program\": {}}}",
                        optp.len(),
                        rep.to_json(),
                        optp.to_json()
                    )
                } else {
                    String::new()
                };
                out.push_str(&format!(
                    "{{\"mode\": \"{}\", \"len\": {}, \"program\": {}{opt_field}}}",
                    mode.label(),
                    program.len(),
                    program.to_json()
                ));
            }
            // an uneven naive split is a *plan* property of this bundle,
            // not a lowering bug — record it instead of failing the dump
            Err(lr_cnn::Error::InfeasiblePlan(msg)) => {
                out.push_str(&format!(
                    "{{\"mode\": \"{}\", \"infeasible\": \"{}\"}}",
                    mode.label(),
                    msg.replace('"', "'")
                ));
            }
            Err(e) => return Err(format!("{}: {e}", mode.label())),
        }
        out.push_str(if i + 1 < Mode::ALL.len() { ",\n" } else { "\n" });
    }
    out.push_str("]\n");
    match flags.get("out").filter(|p| !p.is_empty()) {
        Some(path) => {
            std::fs::write(path, &out).map_err(|e| e.to_string())?;
            eprintln!("wrote row-program IR for {} modes to {path}", Mode::ALL.len());
        }
        None => print!("{out}"),
    }
    Ok(())
}

/// `plan --lint`: the static-analysis sweep (docs/ANALYSIS.md).  Every
/// mode's lowered program is analyzed serially and — with `--devices N`,
/// default 2 — sharded under each partition policy, so the shard
/// race/transfer checker runs on real lowered plans.  Diagnostics render
/// as tables; `--lint-out FILE` writes the machine-readable JSON report
/// (the CI artifact).  Any error-severity diagnostic fails the command.
fn cmd_lint(flags: &HashMap<String, String>) -> Result<(), String> {
    use lr_cnn::rowir::{self, analysis, Mode};
    use lr_cnn::runtime::Manifest;
    use lr_cnn::shard::ShardPlan;

    let man = match flags.get("artifacts").filter(|d| !d.is_empty()) {
        Some(dir) => Manifest::load(std::path::Path::new(dir)).map_err(|e| e.to_string())?,
        None => {
            eprintln!("plan --lint: no --artifacts given, linting the built-in demo bundle");
            Manifest::demo(2)
        }
    };
    let devices: usize = flags
        .get("devices")
        .map(String::as_str)
        .unwrap_or("2")
        .parse()
        .map_err(|_| "bad --devices")?;
    let mut entries: Vec<String> = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    let mut clean = 0usize;
    let esc = lr_cnn::util::json::escape;
    let record = |entries: &mut Vec<String>, mode: Mode, scope: &str, rep: &analysis::Report| {
        entries.push(format!(
            "{{\"mode\": \"{}\", \"scope\": \"{}\", \"report\": {}}}",
            esc(mode.label()),
            esc(scope),
            rep.to_json()
        ));
        if rep.is_clean() {
            println!("lint {:<18} {:<12} clean", mode.label(), scope);
        } else {
            rep.to_table(format!("{} [{scope}] lint", mode.label())).print();
        }
    };
    for mode in Mode::ALL {
        let program = match rowir::lower(&man, mode) {
            Ok(p) => p,
            // an uneven naive split is a plan property of this bundle,
            // not a lint finding (same contract as --dump-ir)
            Err(lr_cnn::Error::InfeasiblePlan(msg)) => {
                entries.push(format!(
                    "{{\"mode\": \"{}\", \"scope\": \"serial\", \"infeasible\": \"{}\"}}",
                    esc(mode.label()),
                    esc(&msg)
                ));
                println!(
                    "lint {:<18} {:<12} infeasible on this bundle (skipped)",
                    mode.label(),
                    "serial"
                );
                continue;
            }
            Err(e) => {
                // the in-lowering gate already failed: surface it as this
                // mode's finding and keep sweeping the other modes
                entries.push(format!(
                    "{{\"mode\": \"{}\", \"scope\": \"serial\", \"error\": \"{}\"}}",
                    esc(mode.label()),
                    esc(&e.to_string())
                ));
                failures.push(format!("{} [serial]: {e}", mode.label()));
                continue;
            }
        };
        let rep = rowir::analysis::analyze(program.graph());
        if rep.has_errors() {
            failures.push(format!("{} [serial]: {}", mode.label(), rep.verdict()));
        } else {
            clean += 1;
        }
        record(&mut entries, mode, "serial", &rep);
        if devices < 2 {
            continue;
        }
        for policy in [
            PartitionPolicy::Blocked,
            PartitionPolicy::CostBalanced,
            PartitionPolicy::DpBoundary,
        ] {
            let scope = format!("{policy:?}@{devices}");
            let topo = ShardConfig::new(devices).topology();
            match ShardPlan::build(program.graph(), &topo, policy, vec![u64::MAX; devices]) {
                Ok(plan) => {
                    let rep = plan.analyze();
                    if rep.has_errors() {
                        failures.push(format!("{} [{scope}]: {}", mode.label(), rep.verdict()));
                    } else {
                        clean += 1;
                    }
                    record(&mut entries, mode, &scope, &rep);
                }
                Err(e) => {
                    entries.push(format!(
                        "{{\"mode\": \"{}\", \"scope\": \"{}\", \"error\": \"{}\"}}",
                        esc(mode.label()),
                        esc(&scope),
                        esc(&e.to_string())
                    ));
                    failures.push(format!("{} [{scope}]: {e}", mode.label()));
                }
            }
        }
    }
    if let Some(path) = flags.get("lint-out").filter(|p| !p.is_empty()) {
        let json = format!(
            "{{\n  \"kind\": \"lr-cnn-lint-report\",\n  \"failing\": {},\n  \"entries\": [\n    {}\n  ]\n}}\n",
            failures.len(),
            entries.join(",\n    ")
        );
        std::fs::write(path, json).map_err(|e| format!("--lint-out {path}: {e}"))?;
        eprintln!("wrote lint report ({} entries) to {path}", entries.len());
    }
    if failures.is_empty() {
        println!("lint: {clean} graph(s) statically clean");
        Ok(())
    } else {
        Err(format!(
            "lint: {} failing graph(s): {}",
            failures.len(),
            failures.join("; ")
        ))
    }
}

/// `plan --optimize`: the optimizer-impact sweep — lower every mode,
/// run the `rowir::opt` fixpoint pipeline at `--opt-level` (default 2)
/// and print one before/after row per mode plus each mode's per-pass
/// table when anything rewrote.  The command itself re-checks the
/// pipeline's core guarantee (post-opt static peak never above pre-opt)
/// so CI catches a regression even without the test suite.
fn cmd_optimize(flags: &HashMap<String, String>) -> Result<(), String> {
    use lr_cnn::rowir::{self, analysis, Mode, OptContext};
    use lr_cnn::runtime::Manifest;
    let man = match flags.get("artifacts").filter(|d| !d.is_empty()) {
        Some(dir) => Manifest::load(std::path::Path::new(dir)).map_err(|e| e.to_string())?,
        None => {
            eprintln!("plan --optimize: no --artifacts given, using the built-in demo bundle");
            Manifest::demo(2)
        }
    };
    let level: u8 = flags
        .get("opt-level")
        .filter(|s| !s.is_empty())
        .map(String::as_str)
        .unwrap_or("2")
        .parse()
        .map_err(|_| "bad --opt-level (0|1|2)")?;
    let mut table = Table::new(
        format!("optimizer impact (level {})", level.min(2)),
        &["mode", "nodes", "peak before", "peak after", "rewrites", "iters"],
    );
    let mut details: Vec<Table> = Vec::new();
    for mode in Mode::ALL {
        let program = match rowir::lower(&man, mode) {
            Ok(p) => p,
            Err(lr_cnn::Error::InfeasiblePlan(_)) => {
                table.row(vec![
                    mode.label().into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "infeasible".into(),
                    "-".into(),
                ]);
                continue;
            }
            Err(e) => return Err(format!("{}: {e}", mode.label())),
        };
        let before = analysis::static_peak(program.graph());
        let (opt, rep) = rowir::optimize(&program, level, &OptContext::serial())
            .map_err(|e| format!("{}: {e}", mode.label()))?;
        let after = analysis::static_peak(opt.graph());
        if after > before {
            return Err(format!(
                "{}: optimizer raised the static peak ({before} -> {after} B)",
                mode.label()
            ));
        }
        table.row(vec![
            mode.label().into(),
            format!("{} -> {}", program.len(), opt.len()),
            fmt_bytes(before),
            fmt_bytes(after),
            rep.rewrites().to_string(),
            rep.iterations.to_string(),
        ]);
        if rep.rewrites() > 0 {
            details.push(rep.to_table(format!("{} passes", mode.label())));
        }
    }
    table.print();
    for t in details {
        println!();
        t.print();
    }
    Ok(())
}

fn cmd_plan(flags: &HashMap<String, String>) -> Result<(), String> {
    if flags.contains_key("dump-ir") {
        return cmd_dump_ir(flags);
    }
    if flags.contains_key("lint") {
        return cmd_lint(flags);
    }
    if flags.contains_key("optimize") {
        return cmd_optimize(flags);
    }
    let net = net_by_name(flags.get("net").map(String::as_str).unwrap_or("vgg16"))
        .ok_or("unknown --net (vgg16|resnet50|minivgg)")?;
    let dev = device_by_name(flags.get("device").map(String::as_str).unwrap_or("rtx3090"))
        .ok_or("unknown --device (rtx3090|rtx3080|a100)")?;
    let b: usize = flags
        .get("batch")
        .map(String::as_str)
        .unwrap_or("8")
        .parse()
        .map_err(|_| "bad --batch")?;
    let default_dim = net.h.to_string();
    let h: usize = flags
        .get("dim")
        .map(String::as_str)
        .unwrap_or(&default_dim)
        .parse()
        .map_err(|_| "bad --dim")?;
    let n_rows: usize = flags
        .get("rows")
        .map(String::as_str)
        .unwrap_or("8")
        .parse()
        .map_err(|_| "bad --rows")?;
    println!(
        "planning {} B={} {}x{} on {} ({} usable)",
        net.name,
        b,
        h,
        h,
        dev.name,
        fmt_bytes(dev.usable_hbm())
    );
    let mut table = Table::new(
        format!("{} iteration plan", net.name),
        &["strategy", "peak", "peak+xi", "fits", "rel. latency", "peak at"],
    );
    let base_cost = Base.cost(&net, b, h, h).map_err(|e| e.to_string())?;
    for s in strategies(&net, &dev, n_rows) {
        let xi = s.xi(&net);
        match s.schedule(&net, b, h, h) {
            Ok(sched) => {
                let rep = sim::simulate(&sched).map_err(|e| e.to_string())?;
                let fits = rep.peak_bytes + xi < dev.usable_hbm();
                let rel = s
                    .cost(&net, b, h, h)
                    .map(|c| format!("{:.2}x", c.relative_to(&base_cost, &dev)))
                    .unwrap_or_else(|_| "-".into());
                table.row(vec![
                    s.name(),
                    fmt_bytes(rep.peak_bytes),
                    fmt_bytes(rep.peak_bytes + xi),
                    if fits { "yes" } else { "OOM" }.into(),
                    rel,
                    rep.peak_at,
                ]);
            }
            Err(e) => table.row(vec![
                s.name(),
                "-".into(),
                "-".into(),
                "infeasible".into(),
                "-".into(),
                e.to_string().chars().take(40).collect(),
            ]),
        }
    }
    table.print();
    Ok(())
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<(), CliError> {
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".into());
    let mode = match flags.get("mode").map(String::as_str).unwrap_or("overl-h") {
        "base" => Mode::Base,
        "overl-h" => Mode::RowHybrid,
        "2ps" => Mode::Tps,
        "naive" => Mode::Naive,
        other => return Err(format!("unknown --mode {other}").into()),
    };
    let steps: u64 = flags
        .get("steps")
        .map(String::as_str)
        .unwrap_or("100")
        .parse()
        .map_err(|_| "bad --steps")?;
    let lr: f32 = flags
        .get("lr")
        .map(String::as_str)
        .unwrap_or("0.02")
        .parse()
        .map_err(|_| "bad --lr")?;
    let workers: usize = flags
        .get("workers")
        .map(String::as_str)
        .unwrap_or("0")
        .parse()
        .map_err(|_| "bad --workers")?;
    let devices_flag: usize = flags
        .get("devices")
        .map(String::as_str)
        .unwrap_or("1")
        .parse()
        .map_err(|_| "bad --devices")?;
    let specs: Option<Vec<DeviceSpec>> = flags
        .get("device-spec")
        .map(|s| DeviceSpec::parse_list(s))
        .transpose()
        .map_err(|e| e.to_string())?;
    let devices = specs.as_ref().map(Vec::len).unwrap_or(devices_flag);
    if let Some(s) = &specs {
        if flags.contains_key("devices") && devices_flag != s.len() {
            eprintln!(
                "warning: --devices {devices_flag} overridden by --device-spec \
                 ({} devices)",
                s.len()
            );
        }
    }
    let policy = match flags.get("policy").map(String::as_str).unwrap_or("blocked") {
        "blocked" => PartitionPolicy::Blocked,
        "balanced" => PartitionPolicy::CostBalanced,
        "dp" | "dp-boundary" => PartitionPolicy::DpBoundary,
        other => {
            return Err(format!("unknown --policy {other} (blocked|balanced|dp)").into())
        }
    };
    let link = match flags.get("link").map(String::as_str).unwrap_or("pcie") {
        "pcie" => LinkKind::Pcie,
        "nvlink" => LinkKind::NvLink,
        other => return Err(format!("unknown --link {other} (pcie|nvlink)").into()),
    };
    // fault-injection knobs (docs/RESILIENCE.md); `random:SEED[:COUNT]`
    // draws a deterministic schedule over this run's steps and devices
    let fault_plan = match flags.get("fault-plan").filter(|s| !s.is_empty()) {
        None => None,
        Some(spec) => Some(match spec.strip_prefix("random:") {
            Some(rest) => {
                let mut it = rest.split(':');
                let seed: u64 = it
                    .next()
                    .unwrap_or("")
                    .parse()
                    .map_err(|_| "bad --fault-plan random seed (random:SEED[:COUNT])")?;
                let count: usize = match it.next() {
                    Some(c) => c
                        .parse()
                        .map_err(|_| "bad --fault-plan random count (random:SEED[:COUNT])")?,
                    None => 4,
                };
                FaultPlan::random(seed, steps, devices, count)
            }
            None => FaultPlan::parse(spec).map_err(CliError::Run)?,
        }),
    };
    let retry = match flags.get("retry").filter(|s| !s.is_empty()) {
        None => RetryPolicy::default(),
        Some(s) => {
            let mut it = s.split(':');
            let max: u32 = it
                .next()
                .unwrap_or("")
                .parse()
                .map_err(|_| "bad --retry (N[:BACKOFF_US])")?;
            match it.next() {
                Some(us) => {
                    let us: f64 =
                        us.parse().map_err(|_| "bad --retry backoff (N[:BACKOFF_US])")?;
                    RetryPolicy::new(max).with_backoff(us * 1e-6)
                }
                None => RetryPolicy::new(max),
            }
        }
    };
    let on_device_lost = match flags.get("on-device-lost").map(String::as_str) {
        None => DeviceLostPolicy::default(),
        Some(s) => DeviceLostPolicy::parse(s)
            .ok_or_else(|| format!("unknown --on-device-lost {s} (fail|degrade)"))?,
    };
    let faulty = fault_plan.is_some()
        || flags.contains_key("retry")
        || flags.contains_key("on-device-lost");
    if devices <= 1 {
        // partition/link flags only matter with 2+ devices; a benchmark
        // invocation passing them with one device would silently
        // misreport its configuration
        for flag in ["policy", "link"] {
            if flags.contains_key(flag) {
                eprintln!(
                    "warning: --{flag} is ignored with {devices} device(s) — pass \
                     --devices N > 1 or a multi-device --device-spec"
                );
            }
        }
        if specs.is_some() && workers == 0 {
            eprintln!(
                "warning: --device-spec is ignored in serial mode — pass --workers N \
                 to enable the pipelined scheduler"
            );
        }
    }
    let rt = if flags.contains_key("demo") {
        if flags.contains_key("artifacts") {
            eprintln!("warning: --artifacts is ignored with --demo (offline backend)");
        }
        Runtime::demo()
    } else {
        Runtime::open(dir).map_err(CliError::Run)?
    };
    println!(
        "platform {} | model {} | mode {}",
        rt.platform(),
        rt.manifest.model.name,
        mode.label()
    );
    let m = rt.manifest.model.clone();
    let corpus = SyntheticCorpus::new(m.n_classes, 3, m.h, m.w, 1234);
    let mut tr = Trainer::new(&rt, mode, lr, 7).map_err(CliError::Run)?;
    if faulty && workers == 0 && devices <= 1 {
        eprintln!(
            "warning: --fault-plan/--retry/--on-device-lost are inert in serial mode — \
             pass --workers N (and --devices M) to exercise the sharded executor"
        );
    }
    if workers > 0 || devices > 1 {
        // a single-device --device-spec is honored too: its admission
        // budget clamps to *that* device's memory, not a default rtx3090
        let shard = match &specs {
            Some(s) => ShardConfig::heterogeneous(s.clone()),
            None => ShardConfig::new(devices),
        }
        .with_policy(policy)
        .with_link(link);
        let names: Vec<String> = shard.devices.iter().map(|d| d.model().name).collect();
        let cfg = SchedConfig::pipelined(workers.max(1)).with_shard(shard);
        tr.set_sched(cfg).map_err(CliError::Run)?;
        tr.set_faults(FaultConfig {
            plan: fault_plan.clone(),
            retry,
            on_device_lost,
        });
        if let Some(p) = &fault_plan {
            println!(
                "faults: {} spec(s) [{} device-loss], retry x{}, on-device-lost {:?}",
                p.specs.len(),
                p.device_lost_count(),
                retry.max_attempts,
                on_device_lost
            );
        }
        if let Some(ss) = tr.shard_state() {
            println!(
                "sched: {} worker(s), {} device(s) [{}], {} transfer(s)/step, modeled link {:.1} us/step",
                workers.max(1),
                names.len(),
                names.join(","),
                ss.plan().transfers().len(),
                ss.plan().modeled_transfer_seconds() * 1e6
            );
        }
    }
    let opt_level: u8 = flags
        .get("opt-level")
        .filter(|s| !s.is_empty())
        .map(String::as_str)
        .unwrap_or("0")
        .parse()
        .map_err(|_| "bad --opt-level (0|1|2)")?;
    if opt_level > 0 {
        // after set_sched: set_opt_level re-lowers, optimizes and
        // rebuilds the active schedule, so a sharded plan gets its
        // post-partition pipeline run too
        tr.set_opt_level(opt_level).map_err(CliError::Run)?;
        if let Some(r) = tr.opt_report() {
            println!(
                "opt: level {}, {} rewrite(s) in {} iteration(s), peak {} -> {}, \
                 {} freed for {:.1} us/step recompute",
                opt_level.min(2),
                r.rewrites(),
                r.iterations,
                fmt_bytes(r.total_peak_before()),
                fmt_bytes(r.total_peak_after()),
                fmt_bytes(r.bytes_freed),
                r.recompute_seconds_added * 1e6
            );
        }
    }
    if flags.contains_key("lint-strict") {
        // gate *after* set_sched (and --opt-level) so the plan that will
        // actually run — sharded and optimized — is what gets judged
        match tr.plan_lint_report() {
            Some(rep) if rep.is_clean() => {
                println!("lint: plan statically clean ({} pass(es))", rep.passes.len());
            }
            Some(rep) => {
                rep.to_table("plan lint").print();
                return Err(CliError::Run(Error::Sched(format!(
                    "--lint-strict: plan is not statically clean ({})",
                    rep.verdict()
                ))));
            }
            None => eprintln!("--lint-strict: no lowered plan to lint (base mode?)"),
        }
    }
    let report_out = flags.get("report-out").filter(|p| !p.is_empty());
    let perfetto_out = flags.get("perfetto-out").filter(|p| !p.is_empty());
    let flight_out = flags.get("flight-out").filter(|p| !p.is_empty());
    let recal_every: u32 = flags
        .get("recalibrate-every")
        .map(String::as_str)
        .unwrap_or("0")
        .parse()
        .map_err(|_| "bad --recalibrate-every")?;
    let recording =
        report_out.is_some() || perfetto_out.is_some() || flight_out.is_some() || recal_every > 0;
    if recording {
        // after set_sched, so the recorder sizes to the final worker pool
        tr.set_recording(true);
        tr.recalibrate_every(recal_every);
    }
    let losses = match train_loop(&mut tr, &corpus, steps, (steps / 20).max(1)) {
        Ok(l) => l,
        Err(e) => {
            // the flight recorder exists for exactly this moment: dump the
            // crash report (bounded ring of recent dispatches + noted
            // events + metrics) before the error propagates
            if let Some(path) = flight_out {
                if let Some(json) = tr.flight_json(&e.to_string()) {
                    match std::fs::write(path, json) {
                        Ok(()) => eprintln!("wrote flight crash report to {path}"),
                        Err(io) => eprintln!("--flight-out {path}: {io}"),
                    }
                }
            }
            return Err(CliError::Run(e));
        }
    };
    if report_out.is_some() || perfetto_out.is_some() {
        // refit the cost model over the recorded spans so the report's
        // calibration section (before/after error) is populated
        if let Some(cal) = tr.calibrate() {
            println!(
                "calibration: {} span(s) fitted, makespan rel err {:.1}% -> {:.1}%",
                cal.samples,
                cal.before_mre * 100.0,
                cal.after_mre * 100.0
            );
        }
    }
    if let Some(path) = report_out {
        match tr.report_json() {
            Some(json) => {
                std::fs::write(path, json)
                    .map_err(|e| CliError::Other(format!("--report-out {path}: {e}")))?;
                println!("wrote run report to {path} — render with `lr-cnn report --in {path}`");
            }
            None => eprintln!("--report-out: no report recorded"),
        }
    }
    if let Some(path) = perfetto_out {
        match tr.perfetto_json() {
            Some(json) => {
                std::fs::write(path, json)
                    .map_err(|e| CliError::Other(format!("--perfetto-out {path}: {e}")))?;
                println!("wrote unified trace to {path} — open in ui.perfetto.dev");
            }
            None => eprintln!("--perfetto-out: no spans recorded"),
        }
    }
    if let Some(path) = flight_out {
        match tr.flight_json("on-demand (--flight-out)") {
            Some(json) => {
                std::fs::write(path, json)
                    .map_err(|e| CliError::Other(format!("--flight-out {path}: {e}")))?;
                println!("wrote flight report to {path}");
            }
            None => eprintln!("--flight-out: no spans recorded"),
        }
    }
    if let Some(path) = flags.get("trace-out") {
        match tr.trace_json() {
            Some(json) => {
                std::fs::write(path, json)
                    .map_err(|e| CliError::Other(format!("--trace-out {path}: {e}")))?;
                println!("wrote per-device trace to {path}");
            }
            None => eprintln!("--trace-out: no trace recorded (zero steps?)"),
        }
    }
    let head = losses.iter().take(10).sum::<f32>() / losses.len().min(10) as f32;
    let tail = losses.iter().rev().take(10).sum::<f32>() / losses.len().min(10) as f32;
    println!(
        "loss {head:.4} -> {tail:.4} over {} steps | runtime stats: {:?}",
        losses.len(),
        rt.stats()
    );
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> Result<(), String> {
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".into());
    let rt = Runtime::open(dir).map_err(|e| e.to_string())?;
    let m = &rt.manifest;
    println!(
        "model {} | {}x{}x3 batch {} | {} conv/pool layers | fc_in {}",
        m.model.name,
        m.model.h,
        m.model.w,
        m.model.batch,
        m.model.layers.len(),
        m.model.fc_in
    );
    let mut t = Table::new("executables", &["name", "kind", "inputs", "outputs"]);
    for e in &m.executables {
        t.row(vec![
            e.name.clone(),
            e.kind.clone(),
            e.inputs.len().to_string(),
            e.outputs.len().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_trace(flags: &HashMap<String, String>) -> Result<(), String> {
    let net = net_by_name(flags.get("net").map(String::as_str).unwrap_or("vgg16"))
        .ok_or("unknown --net")?;
    let dev = device_by_name(flags.get("device").map(String::as_str).unwrap_or("rtx3090"))
        .ok_or("unknown --device")?;
    let b: usize = flags
        .get("batch")
        .map(String::as_str)
        .unwrap_or("8")
        .parse()
        .map_err(|_| "bad --batch")?;
    let n: usize = flags
        .get("rows")
        .map(String::as_str)
        .unwrap_or("8")
        .parse()
        .map_err(|_| "bad --rows")?;
    let name = flags.get("strategy").map(String::as_str).unwrap_or("overl-h");
    let strat: Box<dyn Strategy> = match name {
        "base" => Box::new(Base),
        "ckp" => Box::new(Ckp::auto(&net)),
        "offload" => Box::new(OffLoad::full(&dev)),
        "tsplit" => Box::new(Tsplit::auto(&dev)),
        "2ps" => Box::new(RowCentric::new(RowMode::TwoPhase, n)),
        "overl" => Box::new(RowCentric::new(RowMode::Overlap, n)),
        "2ps-h" | "overl-h" => {
            let cks = lr_cnn::planner::checkpoint::pool_boundary_checkpoints(
                &net,
                (net.layers.len() as f64).sqrt().ceil() as usize,
            );
            let mode = if name.starts_with("2ps") { RowMode::TwoPhase } else { RowMode::Overlap };
            Box::new(RowCentric::hybrid(mode, n, cks))
        }
        other => return Err(format!("unknown --strategy {other}")),
    };
    let sched = strat.schedule(&net, b, net.h, net.w).map_err(|e| e.to_string())?;
    let trace = lr_cnn::memory::trace::to_chrome_trace(&sched, &strat.name())
        .map_err(|e| e.to_string())?;
    let default_out = format!("{}_{}_trace.json", net.name, name);
    let out = flags.get("out").map(String::as_str).unwrap_or(&default_out);
    std::fs::write(out, trace).map_err(|e| e.to_string())?;
    let rep = sim::simulate(&sched).map_err(|e| e.to_string())?;
    println!(
        "wrote {out} ({} events, peak {} at {}) — open in chrome://tracing",
        sched.events.len(),
        fmt_bytes(rep.peak_bytes),
        rep.peak_at
    );
    Ok(())
}

/// `report --in FILE`: render a `train --report-out` JSON as tables.
fn cmd_report(flags: &HashMap<String, String>) -> Result<(), String> {
    let path = flags
        .get("in")
        .filter(|p| !p.is_empty())
        .ok_or("report: pass --in FILE (a `train --report-out` JSON)")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let rep = lr_cnn::obs::RunReport::from_json(&text).map_err(|e| format!("{path}: {e}"))?;
    for t in rep.tables() {
        t.print();
        println!();
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("usage: lr-cnn <plan|train|info|trace|report> [flags]");
            return ExitCode::FAILURE;
        }
    };
    let flags = parse_flags(&rest);
    let res: Result<(), CliError> = match cmd {
        "plan" => cmd_plan(&flags).map_err(CliError::Other),
        "train" => cmd_train(&flags),
        "info" => cmd_info(&flags).map_err(CliError::Other),
        "trace" => cmd_trace(&flags).map_err(CliError::Other),
        "report" => cmd_report(&flags).map_err(CliError::Other),
        other => Err(CliError::Usage(format!("unknown command {other}"))),
    };
    match res {
        Ok(()) => ExitCode::SUCCESS,
        Err(CliError::Usage(msg)) => {
            eprintln!("error: {msg}");
            ExitCode::from(2)
        }
        Err(CliError::Other(msg)) => {
            eprintln!("error: {msg}");
            ExitCode::FAILURE
        }
        Err(CliError::Run(e)) => {
            eprintln!("error: {e}");
            match &e {
                Error::DeviceLost { .. } => eprintln!(
                    "hint: --on-device-lost degrade re-partitions over the surviving \
                     devices when their ledgers can still hold the step"
                ),
                Error::Retryable { attempts, .. } => eprintln!(
                    "hint: raise --retry beyond {attempts} to absorb longer \
                     transient-fault bursts"
                ),
                _ => {}
            }
            ExitCode::from(error_code(&e))
        }
    }
}
