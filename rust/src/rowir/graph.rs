//! The row dependency graph — structure half of the IR.
//!
//! The paper's dependency structure maps directly onto edges:
//!
//! * **OverL / naive rows** are fully independent — no edges between them
//!   (§III-B: halo slabs replicate the overlap instead of sharing it);
//! * **2PS rows** are weakly dependent — row *r* waits only on row *r−1*'s
//!   boundary-cache handoff, so the 2PS forward is exactly a chain;
//! * **barriers** synchronize at the checkpoint/segment boundaries, the
//!   FP→BP boundary (the FC head), and the deterministic gradient
//!   reductions.
//!
//! The graph is **acyclic by construction**: [`Graph::push`] only accepts
//! dependencies on already-pushed nodes (`dep < id`), so node ids are a
//! topological order — the order the serial interpreter executes and the
//! order every reduction barrier folds its inputs in.  [`Graph::validate`]
//! re-checks the full invariant set (acyclicity, deps sorted and
//! deduplicated, labels unique) for graphs that cross an API boundary.

use std::collections::HashSet;

use crate::error::{Error, Result};

use super::task::Task;

/// Index into [`Graph::nodes`]; ids are assigned in push order and form a
/// topological order of the graph.
pub type NodeId = usize;

/// What a node represents *structurally* — drives trace attribution, the
/// shard partitioner's fan detection, and lets property tests state shape
/// invariants ("2PS rows form a chain") without reading tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NodeKind {
    /// Independent row work (OverL/naive FP or BP row): no edges between
    /// rows of the same phase.
    Row,
    /// 2PS row: depends only on its predecessor's boundary caches.
    TpsRow,
    /// Synchronization / reduction point (segment concat, FC head,
    /// deterministic gradient accumulation).
    Barrier,
    /// Cross-device copy inserted by `shard::ShardPlan::lower` when an
    /// edge crosses a device boundary.  Carries the payload bytes as both
    /// `est_bytes` (charged to the destination ledger while the copy is
    /// in flight) and `out_bytes` (the received slab parked until every
    /// consumer finishes).  Never appears in a freshly lowered program.
    Transfer,
}

/// One schedulable unit of a step: structure (kind, deps), execution
/// ([`Task`]), and the cost-model inputs (byte estimates) — everything a
/// driver, the admission ledger, the memory replay and the partitioner
/// need, on one record.
#[derive(Debug, Clone)]
pub struct Node {
    pub kind: NodeKind,
    /// Attribution label ("fp.segA.row0", "barrier.ck", ...) — built once
    /// at lowering, never on the step path.  Unique per graph
    /// ([`Graph::validate`] enforces it: `find(label)` must never
    /// silently return the first of several matches).
    pub label: String,
    /// Direct dependencies (sorted ascending, deduplicated, each `<` this
    /// node's id).
    pub deps: Vec<NodeId>,
    /// What the node does when a driver dispatches it.
    pub task: Task,
    /// Projected live bytes while the node runs — the admission-control
    /// currency (staged input slab + produced outputs; always-resident
    /// parameters ξ are excluded).  Also the cost model's per-node input
    /// (`costmodel::node_seconds_for`).
    pub est_bytes: u64,
    /// Bytes of the node's *output* that stay parked in handoff slots
    /// after it finishes, until every consumer has finished (subset of
    /// `est_bytes`).  The admission ledger retains a grant of this size so
    /// the byte bound covers interim slot residency, not just
    /// concurrently-running nodes.  `0` (the [`Graph::push`] default) means
    /// "nothing parked".
    pub out_bytes: u64,
}

/// A step's row dependency graph (the row-program IR).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    nodes: Vec<Node>,
}

impl Graph {
    pub fn new() -> Self {
        Graph::default()
    }

    /// Append an [`Task::Opaque`] node with nothing parked.  `deps` may
    /// contain duplicates (they are removed); every dep must refer to an
    /// already-pushed node.
    ///
    /// Panics on a forward/self dependency — that is a lowering bug, not a
    /// runtime condition (drivers never mutate a graph).
    pub fn push(
        &mut self,
        kind: NodeKind,
        label: impl Into<String>,
        deps: Vec<NodeId>,
        est_bytes: u64,
    ) -> NodeId {
        self.push_task(kind, label, deps, est_bytes, 0, Task::Opaque)
    }

    /// [`Graph::push`] plus an explicit parked-output byte count: the
    /// producer's output grant is retained by the admission ledger until
    /// all consumers finish (interim handoff-slot residency).
    pub fn push_out(
        &mut self,
        kind: NodeKind,
        label: impl Into<String>,
        deps: Vec<NodeId>,
        est_bytes: u64,
        out_bytes: u64,
    ) -> NodeId {
        self.push_task(kind, label, deps, est_bytes, out_bytes, Task::Opaque)
    }

    /// The full constructor: structure + bytes + the node's [`Task`].
    /// The lowering (`rowir::lower`) and the shard transfer rewrite use
    /// this; hand-built graphs usually want [`Graph::push`]/[`Graph::push_out`].
    pub fn push_task(
        &mut self,
        kind: NodeKind,
        label: impl Into<String>,
        mut deps: Vec<NodeId>,
        est_bytes: u64,
        out_bytes: u64,
        task: Task,
    ) -> NodeId {
        let id = self.nodes.len();
        deps.sort_unstable();
        deps.dedup();
        let label = label.into();
        if let Some(&bad) = deps.iter().find(|&&d| d >= id) {
            panic!("node '{label}' (id {id}) depends on not-yet-pushed node {bad}");
        }
        self.nodes.push(Node {
            kind,
            label,
            deps,
            task,
            est_bytes,
            out_bytes,
        });
        id
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Nodes with no dependencies (immediately runnable).
    pub fn roots(&self) -> Vec<NodeId> {
        (0..self.len())
            .filter(|&i| self.nodes[i].deps.is_empty())
            .collect()
    }

    /// Find a node by its label (test/attribution convenience; O(n)).
    /// [`Graph::validate`] guarantees labels are unique, so the match is
    /// the *only* match, not merely the first.
    pub fn find(&self, label: &str) -> Option<NodeId> {
        self.nodes.iter().position(|n| n.label == label)
    }

    /// Largest single admission request — a budget at least this big keeps
    /// the executor's peak under the budget (below it, oversize nodes are
    /// admitted only on an idle pool and the peak is bounded by
    /// `max(budget, max_node_est)`).
    pub fn max_est_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.est_bytes).max().unwrap_or(0)
    }

    /// Number of direct dependents per node — how many consumers must
    /// finish before a parked output grant can be released.
    pub fn consumer_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.len()];
        for node in &self.nodes {
            for &d in &node.deps {
                counts[d] += 1;
            }
        }
        counts
    }

    /// In-crate test hook: corrupt a graph past the `push` invariants so
    /// `validate()`/`analysis` negative paths are reachable (drivers
    /// never mutate a graph, so there is no public mutator to misuse).
    #[cfg(test)]
    pub(crate) fn nodes_mut(&mut self) -> &mut Vec<Node> {
        &mut self.nodes
    }

    /// Re-check every documented invariant for graphs handed across an
    /// API boundary:
    ///
    /// 1. **acyclicity** — every dep `<` its node's id (ids topological);
    /// 2. **deps sorted + deduplicated** — strictly ascending, so barrier
    ///    reductions that fold `deps` in order fold them in serial order
    ///    exactly once;
    /// 3. **labels unique** — `find(label)` resolves to one node (shard
    ///    lowering hands graphs across an API boundary; a duplicate label
    ///    would make label-based lookups silently pick the first match).
    pub fn validate(&self) -> Result<()> {
        let mut labels: HashSet<&str> = HashSet::with_capacity(self.len());
        for (id, n) in self.nodes.iter().enumerate() {
            if let Some(&bad) = n.deps.iter().find(|&&d| d >= id) {
                return Err(Error::Sched(format!(
                    "node '{}' (id {id}) has forward/self dep {bad} — not a DAG",
                    n.label
                )));
            }
            if let Some(w) = n.deps.windows(2).find(|w| w[0] >= w[1]) {
                return Err(Error::Sched(format!(
                    "node '{}' (id {id}) deps not sorted+deduplicated: {} then {}",
                    n.label, w[0], w[1]
                )));
            }
            if !labels.insert(n.label.as_str()) {
                return Err(Error::Sched(format!(
                    "duplicate node label '{}' (second at id {id}) — find() would \
                     silently return the first match",
                    n.label
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_assigns_topological_ids() {
        let mut g = Graph::new();
        let a = g.push(NodeKind::Row, "a", vec![], 10);
        let b = g.push(NodeKind::Row, "b", vec![], 20);
        let c = g.push(NodeKind::Barrier, "c", vec![a, b, b, a], 0); // dups ok
        assert_eq!((a, b, c), (0, 1, 2));
        assert_eq!(g.node(c).deps, vec![0, 1]); // sorted + deduped
        assert_eq!(g.roots(), vec![0, 1]);
        assert_eq!(g.max_est_bytes(), 20);
        assert!(g.validate().is_ok());
        assert_eq!(g.find("b"), Some(1));
        assert_eq!(g.find("zzz"), None);
        assert_eq!(g.consumer_counts(), vec![1, 1, 0]);
        assert_eq!(g.node(a).task, Task::Opaque, "push defaults to Opaque");
    }

    #[test]
    fn push_defaults_to_no_parked_output() {
        let mut g = Graph::new();
        let a = g.push(NodeKind::Row, "a", vec![], 10);
        let b = g.push_out(NodeKind::Row, "b", vec![a], 20, 8);
        assert_eq!(g.node(a).out_bytes, 0);
        assert_eq!(g.node(b).out_bytes, 8);
        let t = g.push_task(NodeKind::Transfer, "xfer.b.d1", vec![b], 8, 8, Task::Transfer);
        assert_eq!(g.node(t).kind, NodeKind::Transfer);
        assert_eq!(g.node(t).task, Task::Transfer);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn push_task_carries_the_task() {
        let mut g = Graph::new();
        let r = g.push_task(
            NodeKind::Row,
            "fp.segA.row1",
            vec![],
            64,
            16,
            Task::FpRow { seg: 0, row: 1 },
        );
        assert_eq!(g.node(r).task, Task::FpRow { seg: 0, row: 1 });
    }

    #[test]
    #[should_panic(expected = "not-yet-pushed")]
    fn forward_dep_panics_at_build() {
        let mut g = Graph::new();
        g.push(NodeKind::Row, "a", vec![3], 0);
    }

    #[test]
    fn validate_catches_hand_broken_acyclicity() {
        let mut g = Graph::new();
        g.push(NodeKind::Row, "a", vec![], 0);
        // corrupt it through the clone-edit path a fuzzer could hit
        let mut broken = g.clone();
        broken.nodes_mut_for_test()[0].deps.push(0); // self-dep
        let err = broken.validate().unwrap_err();
        assert!(err.to_string().contains("not a DAG"), "{err}");
    }

    #[test]
    fn validate_rejects_unsorted_deps() {
        let mut g = Graph::new();
        let a = g.push(NodeKind::Row, "a", vec![], 0);
        let b = g.push(NodeKind::Row, "b", vec![], 0);
        g.push(NodeKind::Barrier, "red", vec![a, b], 0);
        let mut broken = g.clone();
        broken.nodes_mut_for_test()[2].deps = vec![b, a]; // out of order
        let err = broken.validate().unwrap_err();
        assert!(err.to_string().contains("sorted"), "{err}");
    }

    #[test]
    fn validate_rejects_duplicate_deps() {
        let mut g = Graph::new();
        let a = g.push(NodeKind::Row, "a", vec![], 0);
        g.push(NodeKind::Barrier, "red", vec![a], 0);
        let mut broken = g.clone();
        broken.nodes_mut_for_test()[1].deps = vec![a, a]; // duplicate
        let err = broken.validate().unwrap_err();
        assert!(err.to_string().contains("sorted"), "{err}");
    }

    #[test]
    fn validate_rejects_duplicate_labels() {
        let mut g = Graph::new();
        g.push(NodeKind::Row, "row", vec![], 0);
        g.push(NodeKind::Row, "row", vec![], 0); // same label, different node
        let err = g.validate().unwrap_err();
        assert!(err.to_string().contains("duplicate node label"), "{err}");
        // find() on the broken graph demonstrates why: only id 0 reachable
        assert_eq!(g.find("row"), Some(0));
    }

    impl Graph {
        fn nodes_mut_for_test(&mut self) -> &mut Vec<Node> {
            &mut self.nodes
        }
    }
}
