//! What a row-program node *does* — the execution half of the IR.
//!
//! Every [`crate::rowir::Node`] carries exactly one `Task`.  A driver
//! (the serial [`crate::rowir::interp`], the pipelined `sched` executor,
//! the sharded `shard` executor) walks the graph and dispatches each
//! node's task to the mode's handler; there is no side-table mapping node
//! ids to work, so a lowered program cannot drift out of sync with the
//! schedule that runs it.
//!
//! Row/barrier tasks reference plan geometry by *index* (segment, row);
//! the handlers resolve those indices against the trainer's prebuilt
//! `StepPlan` table.  [`Task::Transfer`] marks a cross-device copy
//! inserted by the shard lowering — executed by the pool itself, never
//! handed to a runner.  [`Task::Opaque`] is the default for hand-built
//! graphs (tests, benches, synthetic workloads) whose work is identified
//! by node id alone.

/// One node's work item.  `Copy` so drivers can hand it across the
/// dispatch boundary without touching the graph's lifetime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// The column-centric single-executable step (`Mode::Base`).
    BaseStep,
    /// OverL forward row `row` of segment `seg` (0 = below checkpoint).
    FpRow { seg: usize, row: usize },
    /// Checkpoint barrier: concat of segment A's row outputs.
    CkBarrier,
    /// 2PS forward row: consumes row `row−1`'s boundary caches.
    TpsRow { row: usize },
    /// z^L concat barrier (upper-half rows or the 2PS chain).
    ZlBarrier,
    /// FP→BP boundary: the FC head (loss, dzL, head grads).
    Head,
    /// Backward row of segment B (slab from the checkpoint, δ from dzL).
    BpRowB { row: usize },
    /// Reduce barrier after BP-B: row grads + dz_ck in serial order.
    ReduceB,
    /// Backward row of segment A (slab from x, δ from dz_ck).
    BpRowA { row: usize },
    /// Final reduce: segment A's row grads, emits the step result.
    ReduceA,
    /// Naive (w/o sharing) forward row.
    NaiveFp { row: usize },
    /// Naive z^L concat barrier.
    NaiveZl,
    /// Naive FC head.
    NaiveHead,
    /// Naive backward row.
    NaiveBp { row: usize },
    /// Naive final reduce.
    NaiveReduce,
    /// Cross-device copy (shard lowering).  Drivers execute it themselves
    /// (ledger + trace bookkeeping, modeled latency); runners never see it.
    Transfer,
    /// No intrinsic meaning: the node id is the work item (hand-built
    /// graphs in tests/benches).  The default for [`crate::rowir::Graph::push`].
    Opaque,
}

impl Task {
    /// `true` for the copies the shard lowering inserts — the one task a
    /// driver must execute itself instead of dispatching to a runner.
    pub fn is_transfer(&self) -> bool {
        matches!(self, Task::Transfer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_predicate() {
        assert!(Task::Transfer.is_transfer());
        assert!(!Task::Opaque.is_transfer());
        assert!(!Task::FpRow { seg: 0, row: 1 }.is_transfer());
    }
}
