//! Lowering: manifest + [`Mode`] → one [`RowProgram`].
//!
//! This is the **only** place the paper's dependency structure is encoded
//! (the old codebase carried it twice: a hand-written serial step path and
//! an independent DAG lowering, with equivalence proven empirically per
//! mode).  Every downstream layer — the serial [`super::interp`], the
//! `sched` worker-pool executor, the `shard` partitioner and transfer
//! rewrite, the per-device `memory::sim` replay, the cost model — consumes
//! the program this module emits, so bit-identity across drivers holds by
//! construction: they all run the same nodes with the same tasks, and
//! every floating-point reduction lives inside a barrier task that folds
//! its inputs in id (= serial) order.
//!
//! ## Lowering rules per mode (docs/ROWIR.md)
//!
//! * [`Mode::Base`] — a single [`Task::BaseStep`] node.
//! * [`Mode::RowHybrid`] — segment-A `FpRow`s (edge-free) → `CkBarrier` →
//!   segment-B `FpRow`s (each waits on the checkpoint only) → `ZlBarrier`
//!   → `Head` → `BpRowB`s (gated on head + checkpoint) → `ReduceB` →
//!   `BpRowA`s → `ReduceA`.
//! * [`Mode::Tps`] — like `RowHybrid`, but the upper half is the 2PS
//!   chain: `TpsRow r` depends only on `TpsRow r−1` (the boundary-cache
//!   handoff), and `ZlBarrier` depends on *every* chain row (the concat
//!   consumes every z slab, so parked grants release exactly there).
//! * [`Mode::Naive`] — edge-free `NaiveFp` rows → `NaiveZl` → `NaiveHead`
//!   → `NaiveBp` rows → `NaiveReduce`; errors with
//!   [`Error::InfeasiblePlan`] when the equal split does not divide H.
//!
//! Per-node byte estimates come from the manifest executable signatures
//! (staged input slab + produced outputs; always-resident parameters ξ
//! excluded) — the admission-control currency and the cost-model input.

use crate::error::{Error, Result};
use crate::runtime::manifest::Manifest;
use crate::runtime::ExecHandle;

use super::graph::{Graph, NodeId, NodeKind};
use super::task::Task;
use super::{analysis, Mode, RowProgram};

/// Row extents for the naive equal-split ablation.
///
/// The AOT artifacts are compiled for *equal* slabs (`aot.py` asserts
/// `h % n_rows == 0`), so an uneven split is a planning error — the seed
/// code silently truncated the remainder rows instead, which both
/// under-trained and disagreed with the compiled shapes.
pub fn naive_row_extents(h: usize, n: usize) -> Result<Vec<[usize; 2]>> {
    if n == 0 || h == 0 {
        return Err(Error::InfeasiblePlan(format!(
            "naive split of H={h} into n={n} rows"
        )));
    }
    if h % n != 0 {
        return Err(Error::InfeasiblePlan(format!(
            "naive(w/o sharing) requires n | H: H={h}, n={n} leaves remainder {} — \
             the AOT artifacts are compiled for equal slabs",
            h % n
        )));
    }
    let rh = h / n;
    Ok((0..n).map(|r| [r * rh, (r + 1) * rh]).collect())
}

/// Lower `mode` over `man` into its row program.
///
/// Errors with [`Error::Artifact`] when the manifest lacks an executable
/// or the segment count is wrong, and [`Error::InfeasiblePlan`] when the
/// naive equal split does not divide H.
pub fn lower(man: &Manifest, mode: Mode) -> Result<RowProgram> {
    let h = |name: &str| -> Result<ExecHandle> { man.index_of(name).map(ExecHandle) };
    let mut g = Graph::new();
    match mode {
        Mode::Base => {
            g.push_task(
                NodeKind::Row,
                "base.step",
                vec![],
                est_fwd(man, h("base_step")?),
                0, // terminal: its output is the step result, not interim
                Task::BaseStep,
            );
        }
        Mode::RowHybrid | Mode::Tps => lower_hybrid(man, mode, &mut g)?,
        Mode::Naive => lower_naive(man, &mut g)?,
    }
    // the static gate: a freshly lowered program must pass the full lint
    // (determinism + liveness), not just Graph::validate — a lowering
    // regression fails here, before any driver runs it
    analysis::check_graph(&g)?;
    RowProgram::new(g)
}

fn lower_hybrid(man: &Manifest, mode: Mode, g: &mut Graph) -> Result<()> {
    if man.plan.segments.len() != 2 {
        return Err(Error::Artifact(format!(
            "hybrid plan expects 2 segments, manifest has {}",
            man.plan.segments.len()
        )));
    }
    let h = |name: &str| -> Result<ExecHandle> { man.index_of(name).map(ExecHandle) };
    let (seg0, seg1) = (
        man.plan.segments[0].name.clone(),
        man.plan.segments[1].name.clone(),
    );
    let rows_a = man.plan.segments[0].rows.len();
    let rows_b = man.plan.segments[1].rows.len();

    // ---- FP segment A (OverL rows: edge-free) ----
    let mut fp_a = Vec::with_capacity(rows_a);
    let mut zck_bytes = 0u64;
    for r in 0..rows_a {
        let fwd = h(&format!("{seg0}_row{r}_fwd"))?;
        zck_bytes += est_out0(man, fwd);
        fp_a.push(g.push_task(
            NodeKind::Row,
            format!("fp.{seg0}.row{r}"),
            vec![],
            est_fwd(man, fwd),
            est_out0(man, fwd), // z parked until the ck concat
            Task::FpRow { seg: 0, row: r },
        ));
    }
    // checkpoint barrier: concat of segment A's rows
    let ck = g.push_task(
        NodeKind::Barrier,
        "barrier.ck",
        fp_a,
        zck_bytes,
        zck_bytes, // the checkpoint lives until its last reader (segB reduce)
        Task::CkBarrier,
    );

    // ---- FP upper half: 2PS chain or segment B rows ----
    let (zl_deps, zl_bytes) = if mode == Mode::Tps {
        let n_tps = man.plan.tps.rows.len();
        let mut rows: Vec<NodeId> = Vec::with_capacity(n_tps);
        let mut bytes = 0u64;
        let mut prev_caches = 0usize;
        for r in 0..n_tps {
            let fwd = h(&format!("tps_row{r}_fwd"))?;
            // the weak dependency: row r waits only on row r−1's
            // boundary-cache handoff
            let deps = rows.last().map(|&p| vec![p]).unwrap_or_default();
            rows.push(g.push_task(
                NodeKind::TpsRow,
                format!("fp.tps.row{r}"),
                deps,
                est_tps(man, fwd, prev_caches),
                // z + boundary caches parked until consumed
                est_outs(man, fwd),
                Task::TpsRow { row: r },
            ));
            bytes += est_out0(man, fwd);
            // this row's cache count, staged by row r+1 (outputs are
            // [z, caches...] per the executable signature)
            prev_caches = n_outputs(man, fwd).saturating_sub(1);
        }
        // zL depends on *every* row (the concat consumes every z slab),
        // not just the chain tail — the extra edges are transitively
        // implied, but they make the graph's consumer structure match the
        // data flow so parked z grants release at the concat
        (rows, bytes)
    } else {
        let mut ids: Vec<NodeId> = Vec::with_capacity(rows_b);
        let mut bytes = 0u64;
        for r in 0..rows_b {
            let fwd = h(&format!("{seg1}_row{r}_fwd"))?;
            bytes += est_out0(man, fwd);
            ids.push(g.push_task(
                NodeKind::Row,
                format!("fp.{seg1}.row{r}"),
                vec![ck],
                est_fwd(man, fwd),
                est_out0(man, fwd), // z parked until zL
                Task::FpRow { seg: 1, row: r },
            ));
        }
        (ids, bytes)
    };
    let zl = g.push_task(
        NodeKind::Barrier,
        "barrier.zL",
        zl_deps,
        zl_bytes,
        zl_bytes, // z^L parked until the head consumes it
        Task::ZlBarrier,
    );
    // FP→BP boundary: the FC head
    let head_h = h("head")?;
    let head = g.push_task(
        NodeKind::Barrier,
        "head",
        vec![zl],
        est_fwd(man, head_h),
        // loss + dzL + head grads parked until the segB reduce
        est_outs(man, head_h),
        Task::Head,
    );

    // ---- BP segment B rows (independent given head + ck) ----
    let mut bp_b = Vec::with_capacity(rows_b);
    for r in 0..rows_b {
        let bwd = h(&format!("{seg1}_row{r}_bwd"))?;
        bp_b.push(g.push_task(
            NodeKind::Row,
            format!("bp.{seg1}.row{r}"),
            vec![head, ck],
            est_bwd(man, bwd),
            est_outs(man, bwd), // row grads + dx parked until reduce
            Task::BpRowB { row: r },
        ));
    }
    let mut red_b_deps = bp_b;
    red_b_deps.extend([head, ck]);
    let red_b = g.push_task(
        NodeKind::Barrier,
        format!("barrier.bp.{seg1}"),
        red_b_deps,
        zck_bytes, // dz_ck accumulator
        zck_bytes, // dz_ck parked until the segA rows consume it
        Task::ReduceB,
    );

    // ---- BP segment A rows ----
    let mut bp_a = Vec::with_capacity(rows_a);
    for r in 0..rows_a {
        let bwd = h(&format!("{seg0}_row{r}_bwd"))?;
        bp_a.push(g.push_task(
            NodeKind::Row,
            format!("bp.{seg0}.row{r}"),
            vec![red_b],
            est_bwd(man, bwd),
            est_outs(man, bwd), // row grads parked until reduce
            Task::BpRowA { row: r },
        ));
    }
    let mut red_a_deps = bp_a;
    red_a_deps.push(red_b);
    g.push_task(
        NodeKind::Barrier,
        format!("barrier.bp.{seg0}"),
        red_a_deps,
        0,
        0, // terminal
        Task::ReduceA,
    );
    Ok(())
}

fn lower_naive(man: &Manifest, g: &mut Graph) -> Result<()> {
    let n = man.plan.naive_rows;
    let z_h = man.model.heights.last().copied().unwrap_or(0);
    // the equal split must divide both the input and output heights — the
    // AOT artifacts are compiled for equal slabs
    naive_row_extents(man.model.h, n)?;
    naive_row_extents(z_h, n)?;
    let h = |name: &str| -> Result<ExecHandle> { man.index_of(name).map(ExecHandle) };

    let mut fp = Vec::with_capacity(n);
    let mut zl_bytes = 0u64;
    for r in 0..n {
        let fwd = h(&format!("naive_row{r}_fwd"))?;
        zl_bytes += est_out0(man, fwd);
        fp.push(g.push_task(
            NodeKind::Row,
            format!("naive.fp.row{r}"),
            vec![],
            est_fwd(man, fwd),
            est_out0(man, fwd), // z parked until the zL concat
            Task::NaiveFp { row: r },
        ));
    }
    let zl = g.push_task(
        NodeKind::Barrier,
        "barrier.naive.zL",
        fp,
        zl_bytes,
        zl_bytes, // z^L parked until the head consumes it
        Task::NaiveZl,
    );
    let head_h = h("head")?;
    let head = g.push_task(
        NodeKind::Barrier,
        "naive.head",
        vec![zl],
        est_fwd(man, head_h),
        est_outs(man, head_h), // loss + dzL + head grads until reduce
        Task::NaiveHead,
    );
    let mut bp = Vec::with_capacity(n);
    for r in 0..n {
        let bwd = h(&format!("naive_row{r}_bwd"))?;
        bp.push(g.push_task(
            NodeKind::Row,
            format!("naive.bp.row{r}"),
            vec![head],
            est_bwd(man, bwd),
            est_outs(man, bwd), // row grads parked until reduce
            Task::NaiveBp { row: r },
        ));
    }
    let mut deps = bp;
    deps.push(head);
    g.push_task(
        NodeKind::Barrier,
        "barrier.naive.reduce",
        deps,
        0,
        0, // terminal
        Task::NaiveReduce,
    );
    Ok(())
}

fn shape_bytes(shape: &[usize]) -> u64 {
    (shape.iter().product::<usize>() * 4) as u64
}

fn n_outputs(man: &Manifest, h: ExecHandle) -> usize {
    man.executables
        .get(h.index())
        .map(|e| e.outputs.len())
        .unwrap_or(0)
}

/// Projected bytes of a forward-style node: staged input slab + outputs.
fn est_fwd(man: &Manifest, h: ExecHandle) -> u64 {
    man.executables
        .get(h.index())
        .map(|e| {
            let slab = e.inputs.first().map(|s| shape_bytes(s)).unwrap_or(0);
            let outs: u64 = e.outputs.iter().map(|s| shape_bytes(s)).sum();
            slab + outs
        })
        .unwrap_or(0)
}

/// Projected bytes of a 2PS row: own slab + the boundary caches staged
/// from the predecessor row + outputs (z + this row's caches).  The cache
/// inputs sit between the slab and the parameters in the signature, so
/// counting only `in0` (as [`est_fwd`] does) would hide exactly the bytes
/// the 2PS chain exists to manage from admission control.
fn est_tps(man: &Manifest, h: ExecHandle, caches_in: usize) -> u64 {
    man.executables
        .get(h.index())
        .map(|e| {
            let staged: u64 = e
                .inputs
                .iter()
                .take(1 + caches_in)
                .map(|s| shape_bytes(s))
                .sum();
            let outs: u64 = e.outputs.iter().map(|s| shape_bytes(s)).sum();
            staged + outs
        })
        .unwrap_or(0)
}

/// Projected bytes of a backward-style node: slab + δ slice + outputs.
fn est_bwd(man: &Manifest, h: ExecHandle) -> u64 {
    man.executables
        .get(h.index())
        .map(|e| {
            let slab = e.inputs.first().map(|s| shape_bytes(s)).unwrap_or(0);
            let dz = if e.inputs.len() >= 2 {
                e.inputs.last().map(|s| shape_bytes(s)).unwrap_or(0)
            } else {
                0
            };
            let outs: u64 = e.outputs.iter().map(|s| shape_bytes(s)).sum();
            slab + dz + outs
        })
        .unwrap_or(0)
}

/// Bytes of an executable's first output (a row's z slab — what survives
/// into the concat barrier).
fn est_out0(man: &Manifest, h: ExecHandle) -> u64 {
    man.executables
        .get(h.index())
        .and_then(|e| e.outputs.first())
        .map(|s| shape_bytes(s))
        .unwrap_or(0)
}

/// Total output bytes of an executable — what sits parked in handoff
/// slots between the node's finish and its last consumer's finish (the
/// `Node::out_bytes` currency the admission ledger retains).
fn est_outs(man: &Manifest, h: ExecHandle) -> u64 {
    man.executables
        .get(h.index())
        .map(|e| e.outputs.iter().map(|s| shape_bytes(s)).sum())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    #[test]
    fn naive_row_extents_equal_split() {
        let ivs = naive_row_extents(32, 4).unwrap();
        assert_eq!(ivs.len(), 4);
        assert_eq!(ivs[0], [0, 8]);
        assert_eq!(ivs[3], [24, 32]);
        // cover the full range with no gaps
        for w in ivs.windows(2) {
            assert_eq!(w[0][1], w[1][0]);
        }
    }

    #[test]
    fn naive_row_extents_rejects_remainder() {
        // the seed silently truncated h=33 n=4 to 4×8 rows, dropping row 32
        let err = naive_row_extents(33, 4).unwrap_err();
        match err {
            Error::InfeasiblePlan(msg) => {
                assert!(msg.contains("remainder"), "{msg}");
            }
            other => panic!("expected InfeasiblePlan, got {other:?}"),
        }
        assert!(naive_row_extents(8, 0).is_err());
        assert!(naive_row_extents(0, 2).is_err());
    }

    /// Lowering rules, checked against the paper's dependency structure
    /// verbatim: OverL rows edge-free, 2PS rows exactly chain-shaped,
    /// barriers at the checkpoint / z^L / FP→BP boundaries, tasks carried
    /// on the nodes.
    #[test]
    fn lowered_programs_match_the_papers_dependency_structure() {
        let man = Manifest::demo(2);

        // OverL-H
        let prog = lower(&man, Mode::RowHybrid).unwrap();
        let g = prog.graph();
        assert!(g.validate().is_ok());
        let ck = g.find("barrier.ck").expect("checkpoint barrier");
        let zl = g.find("barrier.zL").expect("zL barrier");
        let head = g.find("head").expect("FP→BP barrier");
        assert_eq!(g.node(ck).task, Task::CkBarrier);
        assert_eq!(g.node(head).task, Task::Head);
        for r in 0..2 {
            let fp_a = g.find(&format!("fp.segA.row{r}")).unwrap();
            assert_eq!(g.node(fp_a).kind, NodeKind::Row);
            assert_eq!(g.node(fp_a).task, Task::FpRow { seg: 0, row: r });
            assert!(g.node(fp_a).deps.is_empty(), "OverL rows are edge-free");
            let fp_b = g.find(&format!("fp.segB.row{r}")).unwrap();
            assert_eq!(g.node(fp_b).deps, vec![ck], "segB row waits on ck only");
            let bp_b = g.find(&format!("bp.segB.row{r}")).unwrap();
            assert!(g.node(bp_b).deps.contains(&head), "BP waits for FP→BP");
            assert_eq!(g.node(bp_b).task, Task::BpRowB { row: r });
        }
        assert_eq!(g.node(head).deps, vec![zl]);
        assert_eq!(g.node(head).kind, NodeKind::Barrier);
        let red_b = g.find("barrier.bp.segB").unwrap();
        let bp_a0 = g.find("bp.segA.row0").unwrap();
        assert_eq!(g.node(bp_a0).deps, vec![red_b]);
        assert!(g.find("barrier.bp.segA").is_some());
        // est_bytes come from the executable signatures
        let fp_a0 = g.find("fp.segA.row0").unwrap();
        assert_eq!(g.node(fp_a0).est_bytes, (5 * 4 + 4 * 4) * 4); // slab+z
        assert_eq!(g.node(ck).est_bytes, 2 * 4 * 4 * 4); // zck

        // 2PS: rows exactly chain-shaped
        let prog = lower(&man, Mode::Tps).unwrap();
        let g = prog.graph();
        assert!(g.validate().is_ok());
        let r0 = g.find("fp.tps.row0").unwrap();
        let r1 = g.find("fp.tps.row1").unwrap();
        assert_eq!(g.node(r0).kind, NodeKind::TpsRow);
        assert_eq!(g.node(r0).task, Task::TpsRow { row: 0 });
        assert!(g.node(r0).deps.is_empty());
        assert_eq!(g.node(r1).deps, vec![r0], "2PS edges are a chain");
        let zl = g.find("barrier.zL").unwrap();
        // the concat consumes every row's z, so zL depends on all rows
        // (the r0 edge is transitively implied by the chain; stating it
        // makes parked z grants release exactly at the concat)
        assert_eq!(g.node(zl).deps, vec![r0, r1], "zL consumes every row");
        // 2PS row estimates include the staged boundary caches:
        // row0 = own 64 + outs (z 64 + 2×16) = 160;
        // row1 = own 64 + 2 caches in (2×16) + z 64 = 160
        assert_eq!(g.node(r0).est_bytes, 160);
        assert_eq!(g.node(r1).est_bytes, 160);

        // naive: rows edge-free, reduce gated on head
        let prog = lower(&man, Mode::Naive).unwrap();
        let g = prog.graph();
        for r in 0..2 {
            let fp = g.find(&format!("naive.fp.row{r}")).unwrap();
            assert!(g.node(fp).deps.is_empty());
            assert_eq!(g.node(fp).task, Task::NaiveFp { row: r });
        }
        let head = g.find("naive.head").unwrap();
        let red = g.find("barrier.naive.reduce").unwrap();
        assert!(g.node(red).deps.contains(&head));
        assert_eq!(g.node(red).task, Task::NaiveReduce);

        // Base: a single step node
        let prog = lower(&man, Mode::Base).unwrap();
        assert_eq!(prog.len(), 1);
        assert_eq!(prog.graph().find("base.step"), Some(0));
        assert_eq!(prog.task(0), Task::BaseStep);
    }

    #[test]
    fn uneven_naive_split_is_a_typed_lowering_error() {
        // h=8, naive_rows=3: 8 % 3 != 0 — the seed truncated, we flag
        let man = Manifest::demo(3);
        match lower(&man, Mode::Naive) {
            Err(Error::InfeasiblePlan(msg)) => assert!(msg.contains("remainder"), "{msg}"),
            other => panic!("expected InfeasiblePlan, got {:?}", other.is_ok()),
        }
        // the other modes are unaffected by the naive split
        assert!(lower(&man, Mode::RowHybrid).is_ok());
    }

    #[test]
    fn missing_executable_is_a_typed_artifact_error() {
        let mut man = Manifest::demo(2);
        man.executables.retain(|e| e.name != "segB_row1_bwd");
        match lower(&man, Mode::RowHybrid) {
            Err(Error::Artifact(msg)) => assert!(msg.contains("segB_row1_bwd"), "{msg}"),
            other => panic!("expected Artifact error, got {:?}", other.is_ok()),
        }
    }
}
