//! `rowir` — the row-program IR, from lowering to execution
//! (docs/ROWIR.md).
//!
//! The paper's core move is breaking the layer-by-layer column dataflow
//! into a row dataflow.  This module makes that dataflow a first-class,
//! **single** artifact: [`lower::lower`] compiles a manifest + [`Mode`]
//! into one [`RowProgram`] — a [`Graph`] whose every [`Node`] carries its
//! structure (kind, deps), its execution ([`Task`]) and its cost-model
//! inputs (byte estimates) — and every downstream layer consumes that one
//! program:
//!
//! * the serial [`interp`] (execute nodes in id order — *the* reference
//!   schedule; there is no hand-written serial step path anymore),
//! * the pipelined `sched` executor (worker pool under memory admission),
//! * the sharded `shard` partitioner/plan (transfers become ordinary IR
//!   nodes carrying [`Task::Transfer`]),
//! * the per-device `memory::sim` replay ([`interp::schedules`] derives
//!   the allocation schedules from an IR walk),
//! * the `costmodel` (per-node seconds from `Node::est_bytes`).
//!
//! Serial, pipelined and sharded are therefore three **drivers of one
//! program**, and bit-identity to serial holds by construction: every
//! driver runs the same tasks, and every floating-point reduction lives
//! inside a barrier task that folds its inputs in id (= serial) order.
//!
//! | module | role |
//! |---|---|
//! | [`graph`] | acyclic-by-construction row dependency graph (task-carrying nodes) |
//! | [`task`] | the node work items, [`Task::Transfer`] included |
//! | [`lower`] | manifest + mode → [`RowProgram`] (the only dataflow encoding) |
//! | [`interp`] | serial driver + IR-walk memory replay |
//! | [`analysis`] | static verification: determinism lint, liveness peak bound, shard-plan checker (docs/ANALYSIS.md) |

pub mod analysis;
pub mod graph;
pub mod interp;
pub mod lower;
pub mod opt;
pub mod task;

pub use graph::{Graph, Node, NodeId, NodeKind};
pub use interp::InterpOutcome;
pub use lower::{lower, naive_row_extents};
pub use opt::{optimize, OptContext, OptReport};
pub use task::Task;

use std::collections::HashMap;

use crate::error::Result;

/// Execution strategy a program is lowered for — the paper's Fig. 11
/// branches plus Base.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// column-centric single-executable step (the paper's Base)
    Base,
    /// OverL-H: segmented halo slabs, checkpoint after pool2
    RowHybrid,
    /// 2PS forward (boundary caches handed between rows) + row-slab BP
    Tps,
    /// broken w/o-sharing ablation (Fig. 11's diverging branch)
    Naive,
}

impl Mode {
    pub fn label(&self) -> &'static str {
        match self {
            Mode::Base => "Base",
            Mode::RowHybrid => "OverL-H",
            Mode::Tps => "2PS",
            Mode::Naive => "naive(w/o sharing)",
        }
    }

    /// All four modes, in the order the proofs and the IR dump sweep them.
    pub const ALL: [Mode; 4] = [Mode::Base, Mode::RowHybrid, Mode::Tps, Mode::Naive];
}

/// A validated, lowered row program: the one artifact every driver runs.
///
/// A `RowProgram` is a [`Graph`] that passed [`Graph::validate`] —
/// acyclic, deps sorted + deduplicated, labels unique.  Construction goes
/// through [`RowProgram::new`], so holding one is proof of validity.
#[derive(Debug, Clone)]
pub struct RowProgram {
    graph: Graph,
    /// task → first node carrying it, built once at construction so
    /// [`RowProgram::find_task`] is O(1) instead of an O(V) scan per
    /// call (the optimizer's dedup maps and the forward-prefix boundary
    /// lookups both hit it in loops).
    task_index: HashMap<Task, NodeId>,
}

impl RowProgram {
    /// Wrap a graph, re-checking every invariant ([`Graph::validate`]).
    pub fn new(graph: Graph) -> Result<RowProgram> {
        graph.validate()?;
        let mut task_index = HashMap::with_capacity(graph.len());
        for (id, node) in graph.nodes().iter().enumerate() {
            // first id wins: same answer `position()` used to give
            task_index.entry(node.task).or_insert(id);
        }
        Ok(RowProgram { graph, task_index })
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn len(&self) -> usize {
        self.graph.len()
    }

    pub fn is_empty(&self) -> bool {
        self.graph.is_empty()
    }

    /// Node `id`'s work item.
    pub fn task(&self, id: NodeId) -> Task {
        self.graph.node(id).task
    }

    /// First node carrying `task` (the forward-prefix boundary lookup) —
    /// an O(1) hit on the index built in [`RowProgram::new`].
    pub fn find_task(&self, task: Task) -> Option<NodeId> {
        self.task_index.get(&task).copied()
    }

    /// Re-run the validity check (paranoia hook for callers receiving a
    /// program across an API boundary; `new` already validated).
    pub fn validate(&self) -> Result<()> {
        self.graph.validate()
    }

    /// Deterministic JSON dump of the lowered program — one object per
    /// node in id order with label, kind, task, deps and byte estimates.
    /// What `lr_cnn plan --dump-ir` emits and the CI smoke step validates.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("{\n  \"schema\": 1,\n  \"nodes\": [\n");
        for (id, node) in self.graph.nodes().iter().enumerate() {
            let deps: Vec<String> = node.deps.iter().map(|d| d.to_string()).collect();
            let _ = write!(
                out,
                "    {{\"id\": {id}, \"label\": \"{}\", \"kind\": \"{:?}\", \
                 \"task\": \"{:?}\", \"deps\": [{}], \"est_bytes\": {}, \
                 \"out_bytes\": {}}}",
                node.label,
                node.kind,
                node.task,
                deps.join(", "),
                node.est_bytes,
                node.out_bytes
            );
            out.push_str(if id + 1 < self.graph.len() { ",\n" } else { "\n" });
        }
        let _ = writeln!(out, "  ],\n  \"len\": {}\n}}", self.graph.len());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_rejects_invalid_graphs() {
        let mut g = Graph::new();
        g.push(NodeKind::Row, "dup", vec![], 1);
        g.push(NodeKind::Row, "dup", vec![], 1);
        assert!(RowProgram::new(g).is_err(), "duplicate labels rejected");
    }

    #[test]
    fn task_lookup_and_json_dump() {
        let mut g = Graph::new();
        let a = g.push_task(NodeKind::Row, "a", vec![], 10, 4, Task::FpRow { seg: 0, row: 0 });
        g.push_task(NodeKind::Barrier, "red", vec![a], 0, 0, Task::ReduceA);
        let p = RowProgram::new(g).unwrap();
        assert_eq!(p.task(a), Task::FpRow { seg: 0, row: 0 });
        assert_eq!(p.find_task(Task::ReduceA), Some(1));
        assert_eq!(p.find_task(Task::Head), None);
        let json = p.to_json();
        assert!(crate::util::json::JsonValue::parse(&json).is_ok(), "{json}");
        assert_eq!(json, p.to_json(), "dump is deterministic");
        assert!(json.contains("\"task\": \"FpRow { seg: 0, row: 0 }\""), "{json}");
        assert!(json.contains("\"est_bytes\": 10"), "{json}");
    }

    #[test]
    fn find_task_index_keeps_first_wins_semantics() {
        let mut g = Graph::new();
        let a = g.push(NodeKind::Row, "a", vec![], 1);
        let _b = g.push(NodeKind::Row, "b", vec![a], 1);
        let p = RowProgram::new(g).unwrap();
        // both nodes carry Opaque: the index answers with the first id,
        // exactly as the old linear scan did
        assert_eq!(p.find_task(Task::Opaque), Some(0));
    }

    #[test]
    fn mode_labels_and_sweep_order() {
        assert_eq!(Mode::ALL.len(), 4);
        assert_eq!(Mode::Base.label(), "Base");
        assert_eq!(Mode::Tps.label(), "2PS");
    }
}
