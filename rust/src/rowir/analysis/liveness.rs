//! Def-use / liveness dataflow core + the static peak-memory bound.
//!
//! The sweep walks nodes in ascending id order — the serial schedule —
//! replaying exactly the event ordering of `interp::run_subset` and the
//! `memory::sim` replay: a node's `est_bytes` working set is charged
//! while it runs, its `out_bytes` park afterwards if anything still
//! reads them, and a parked output is released the moment its last
//! consumer finishes (*after* that consumer parked its own output — the
//! order the ledger uses, so the bound never under-counts the handoff
//! overlap).
//!
//! Because the sweep mirrors the replay event-for-event,
//! [`static_peak`] equals the serial replay peak **exactly** on every
//! graph — in particular on fan graphs — which makes it a sound `>=`
//! admission bound that costs O(V+E) and needs no replay machinery,
//! schedules, or simulator (property-tested against `interp::run` in
//! `tests/analysis_properties.rs`).  [`static_device_peaks`] is the same
//! sweep split over a device assignment, the static twin of
//! `interp::schedules` + `memory::sim::simulate`.

use super::super::graph::{Graph, NodeId};
use super::{Code, Diag, Pass};

/// Def-use facts for one graph, computed in a single O(V+E) sweep.
#[derive(Debug, Clone)]
pub struct Liveness {
    /// Direct consumer count per node (how many readers its parked
    /// output waits for) — `Graph::consumer_counts`.
    pub consumers: Vec<usize>,
    /// Highest-id consumer per node — the point its parked output dies
    /// under the serial schedule.  `None` when nothing reads it.
    pub last_use: Vec<Option<NodeId>>,
    /// The static peak of the serial-order byte ledger (see
    /// [`static_peak`]).
    pub peak_bytes: u64,
}

impl Liveness {
    pub fn of(graph: &Graph) -> Liveness {
        let consumers = graph.consumer_counts();
        let mut last_use: Vec<Option<NodeId>> = vec![None; graph.len()];
        for (id, node) in graph.nodes().iter().enumerate() {
            for &d in &node.deps {
                // ids ascend, so the latest write wins = highest consumer
                last_use[d] = Some(id);
            }
        }
        Liveness {
            consumers,
            last_use,
            peak_bytes: static_peak(graph),
        }
    }

    /// Nodes whose parked output nothing ever reads (dead bytes in the
    /// byte plan).
    pub fn dead_outputs(&self, graph: &Graph) -> Vec<NodeId> {
        (0..graph.len())
            .filter(|&id| graph.node(id).out_bytes > 0 && self.consumers[id] == 0)
            .collect()
    }
}

/// Static peak of the serial-order projected-byte ledger: the exact peak
/// the interpreter replay reports, computed without running anything.
pub fn static_peak(graph: &Graph) -> u64 {
    static_device_peaks(graph, &vec![0; graph.len()], 1)[0]
}

/// [`static_peak`] split over a device assignment: per-device peaks of a
/// serial-order walk, the static twin of `interp::schedules` +
/// `memory::sim::simulate` (and therefore of `ShardPlan::replay_peaks`).
///
/// Event order per node — identical to the replay's:
/// 1. charge `est_bytes` to the node's device while it runs;
/// 2. park `out_bytes` on its device if any consumer remains;
/// 3. release every dep whose last consumer this node was, on the
///    *dep's* device.
pub fn static_device_peaks(graph: &Graph, device_of: &[usize], devices: usize) -> Vec<u64> {
    debug_assert_eq!(device_of.len(), graph.len());
    let mut left = graph.consumer_counts();
    let mut live = vec![0u64; devices];
    let mut peak = vec![0u64; devices];
    for (id, node) in graph.nodes().iter().enumerate() {
        let d = device_of[id];
        peak[d] = peak[d].max(live[d] + node.est_bytes);
        if left[id] > 0 && node.out_bytes > 0 {
            live[d] += node.out_bytes;
            peak[d] = peak[d].max(live[d]);
        }
        for &dep in &node.deps {
            left[dep] -= 1;
            if left[dep] == 0 && graph.node(dep).out_bytes > 0 {
                live[device_of[dep]] -= graph.node(dep).out_bytes;
            }
        }
    }
    peak
}

/// The liveness lint: parked bytes nothing reads are dead weight the
/// admission ledger still has to reserve — suspicious, but safe to run
/// (a closure target legitimately parks nothing because subset consumer
/// counts are what the executors use).  Warning-severity [`Code::DeadOutput`].
pub struct LivenessPass;

impl Pass for LivenessPass {
    fn name(&self) -> &'static str {
        "liveness"
    }

    fn run(&self, graph: &Graph, out: &mut Vec<Diag>) {
        let live = Liveness::of(graph);
        for id in live.dead_outputs(graph) {
            out.push(Diag::warning(
                Code::DeadOutput,
                Some(id),
                format!(
                    "node '{}' parks {} byte(s) no consumer ever reads",
                    graph.node(id).label,
                    graph.node(id).out_bytes
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rowir::graph::NodeKind;
    use crate::rowir::{interp, RowProgram};

    fn fan(rows: usize) -> Graph {
        let mut g = Graph::new();
        let fp: Vec<NodeId> = (0..rows)
            .map(|r| g.push_out(NodeKind::Row, format!("fp{r}"), vec![], 100, 40))
            .collect();
        let head = g.push_out(NodeKind::Barrier, "head", fp, 100, 40);
        let bp: Vec<NodeId> = (0..rows)
            .map(|r| g.push_out(NodeKind::Row, format!("bp{r}"), vec![head], 100, 40))
            .collect();
        g.push(NodeKind::Barrier, "reduce", bp, 0);
        g
    }

    #[test]
    fn static_peak_equals_interp_replay_on_the_fan_shape() {
        for rows in [1, 2, 3, 8] {
            let g = fan(rows);
            let prog = RowProgram::new(g.clone()).unwrap();
            let replay = interp::run(&prog, |_, _| Ok(())).unwrap();
            assert_eq!(static_peak(&g), replay.peak_bytes, "rows={rows}");
        }
    }

    #[test]
    fn parked_bytes_held_until_the_last_consumer() {
        let mut g = Graph::new();
        // a's 100-byte output is read only by c: parked across b's run
        let a = g.push_out(NodeKind::Row, "a", vec![], 100, 100);
        let b = g.push(NodeKind::Row, "b", vec![a], 10);
        g.push(NodeKind::Barrier, "c", vec![a, b], 5);
        assert_eq!(static_peak(&g), 110);
        let live = Liveness::of(&g);
        assert_eq!(live.last_use[a], Some(2));
        assert_eq!(live.last_use[b], Some(2));
        assert_eq!(live.consumers, vec![2, 1, 0]);
        assert!(live.dead_outputs(&g).is_empty());
    }

    #[test]
    fn device_split_matches_the_sim_replay_per_device() {
        use crate::memory::sim;
        let g = fan(2);
        let mut dev = vec![0usize; g.len()];
        dev[1] = 1; // fp1 on device 1
        let stat = static_device_peaks(&g, &dev, 2);
        let scheds = interp::schedules(&g, &dev, 2);
        for (d, s) in scheds.iter().enumerate() {
            assert_eq!(stat[d], sim::simulate(s).unwrap().peak_bytes, "device {d}");
        }
    }

    #[test]
    fn dead_output_is_flagged_as_a_warning() {
        let mut g = Graph::new();
        g.push_out(NodeKind::Row, "orphan", vec![], 10, 8); // nothing reads it
        let mut diags = Vec::new();
        LivenessPass.run(&g, &mut diags);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code, Code::DeadOutput);
        assert_eq!(diags[0].node, Some(0));
        assert_eq!(diags[0].severity, super::super::Severity::Warning);
    }
}
