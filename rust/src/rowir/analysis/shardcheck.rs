//! The shard-plan race/transfer checker.
//!
//! A sharded plan is the base graph re-lowered over devices: every node
//! carries a device, cross-device edges are carried by Transfer nodes,
//! and finished outputs land in *host slots* keyed by the base node that
//! produced them (plus staged copies keyed by (producer, destination
//! device)).  The checker proves, structurally:
//!
//! * [`Code::PlanShape`] — the assignment arrays have the right arity
//!   and every device id is inside the topology (checked first; the
//!   other checks index by them);
//! * [`Code::HostSlotRace`] — no two *unordered* plan nodes (neither an
//!   ancestor of the other — i.e. concurrently admissible under any
//!   executor) write the same host slot;
//! * [`Code::MissingTransfer`] — every cross-device edge terminates in a
//!   Transfer node on the consumer side; a bare cross-device read would
//!   touch another device's memory;
//! * [`Code::TransferEndpoint`] — a Transfer has exactly one source, its
//!   source is on a *different* device, and every consumer is on the
//!   transfer's own device (the slab was staged there and nowhere else);
//! * [`Code::DanglingTransfer`] — a Transfer nothing reads: a copy paid
//!   for and thrown away, which the lowering never emits.
//!
//! The checker takes a [`ShardView`] of plain slices rather than a
//! `ShardPlan` so negative tests can hand-build malformed plans without
//! reaching into `shard`'s private fields; `ShardPlan::analyze` wraps
//! its own state in a view and adds the metadata cross-checks only it
//! can do (transfer records, replay-peak bounds).

use std::collections::HashMap;

use super::super::graph::{Graph, NodeId, NodeKind};
use super::{Code, Diag};

/// A borrowed view of a sharded plan: the lowered graph, the per-node
/// device assignment, the per-node base-graph origin (`None` for
/// inserted Transfers), and the device count.
pub struct ShardView<'a> {
    pub graph: &'a Graph,
    pub device_of: &'a [usize],
    /// Base-graph node each plan node materializes — the host slot it
    /// writes.  `None` for Transfer nodes (they write staged copies).
    pub orig: &'a [Option<NodeId>],
    pub devices: usize,
}

/// Host-slot identity: what a finished node's output overwrites.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Slot {
    /// The base node's result slot.
    Base(NodeId),
    /// A staged copy of `0`'s result on device `1`.
    Staged(NodeId, usize),
}

/// Dense ancestor bitsets: `anc[id]` covers every transitive dep of
/// `id`.  O(V·E/64) to build — plans are step graphs (hundreds of
/// nodes), so this stays trivial next to the replay it replaces.
struct Ancestors {
    words: usize,
    bits: Vec<u64>,
}

impl Ancestors {
    fn of(graph: &Graph) -> Ancestors {
        let n = graph.len();
        let words = n.div_ceil(64);
        let mut bits = vec![0u64; n * words];
        for (id, node) in graph.nodes().iter().enumerate() {
            for &d in &node.deps {
                let (dst, src) = (id * words, d * words);
                for w in 0..words {
                    bits[dst + w] |= bits[src + w];
                }
                bits[dst + d / 64] |= 1 << (d % 64);
            }
        }
        Ancestors { words, bits }
    }

    fn is_ancestor(&self, anc: NodeId, of: NodeId) -> bool {
        self.bits[of * self.words + anc / 64] & (1 << (anc % 64)) != 0
    }

    /// Neither node reaches the other: some executor interleaving runs
    /// them concurrently.
    fn unordered(&self, a: NodeId, b: NodeId) -> bool {
        !self.is_ancestor(a, b) && !self.is_ancestor(b, a)
    }
}

/// Resolve a plan node to the base node whose bytes it carries, looking
/// through Transfer chains.  `None` if the chain dead-ends (malformed —
/// reported separately as an endpoint error).
fn base_of(view: &ShardView, mut id: NodeId) -> Option<NodeId> {
    loop {
        if view.graph.node(id).kind != NodeKind::Transfer {
            return view.orig[id];
        }
        id = *view.graph.node(id).deps.first()?;
    }
}

/// Run every shard-plan check over a view.  Shape errors short-circuit:
/// the remaining checks index by device and origin, so there is nothing
/// sound to say about a malformed view beyond its shape.
pub fn check(view: &ShardView) -> Vec<Diag> {
    let mut out = Vec::new();
    let n = view.graph.len();
    if view.device_of.len() != n || view.orig.len() != n {
        out.push(Diag::error(
            Code::PlanShape,
            None,
            format!(
                "assignment arity mismatch: {} nodes, {} device entries, {} origin entries",
                n,
                view.device_of.len(),
                view.orig.len()
            ),
        ));
        return out;
    }
    for (id, &d) in view.device_of.iter().enumerate() {
        if d >= view.devices {
            out.push(Diag::error(
                Code::PlanShape,
                Some(id),
                format!(
                    "node '{}' assigned to device {d} but the topology has {}",
                    view.graph.node(id).label,
                    view.devices
                ),
            ));
        }
    }
    if !out.is_empty() {
        return out;
    }

    let consumers = view.graph.consumer_counts();
    for (id, node) in view.graph.nodes().iter().enumerate() {
        let dev = view.device_of[id];
        if node.kind == NodeKind::Transfer {
            // endpoints: one source, on another device
            if node.deps.len() != 1 {
                out.push(Diag::error(
                    Code::TransferEndpoint,
                    Some(id),
                    format!(
                        "transfer '{}' has {} source(s); a copy has exactly one",
                        node.label,
                        node.deps.len()
                    ),
                ));
            } else {
                let src = node.deps[0];
                if view.device_of[src] == dev {
                    out.push(Diag::error(
                        Code::TransferEndpoint,
                        Some(id),
                        format!(
                            "transfer '{}' copies within device {dev} — endpoints must \
                             differ",
                            node.label
                        ),
                    ));
                }
            }
            if consumers[id] == 0 {
                out.push(Diag::error(
                    Code::DanglingTransfer,
                    Some(id),
                    format!(
                        "transfer '{}' has no consumers — a copy paid for and thrown away",
                        node.label
                    ),
                ));
            }
        } else {
            // every cross-device edge must terminate in a Transfer on the
            // consumer side, and the consumer of a Transfer must sit on
            // the transfer's device
            for &d in &node.deps {
                let src_dev = view.device_of[d];
                if src_dev == dev {
                    continue;
                }
                let code = if view.graph.node(d).kind == NodeKind::Transfer {
                    Code::TransferEndpoint // staged on src_dev, read from dev
                } else {
                    Code::MissingTransfer
                };
                out.push(Diag::error(
                    code,
                    Some(id),
                    format!(
                        "node '{}' (device {dev}) reads '{}' on device {src_dev} {}",
                        node.label,
                        view.graph.node(d).label,
                        if code == Code::MissingTransfer {
                            "with no transfer carrying the edge"
                        } else {
                            "— the copy was staged on the wrong device"
                        }
                    ),
                ));
            }
        }
    }

    // host-slot races: unordered duplicate writers of one slot
    let anc = Ancestors::of(view.graph);
    let mut writers: HashMap<Slot, Vec<NodeId>> = HashMap::new();
    for (id, node) in view.graph.nodes().iter().enumerate() {
        let slot = if node.kind == NodeKind::Transfer {
            match base_of(view, id) {
                Some(base) => Slot::Staged(base, view.device_of[id]),
                None => continue, // dead-ended chain, already reported
            }
        } else {
            match view.orig[id] {
                Some(base) => Slot::Base(base),
                None => {
                    out.push(Diag::error(
                        Code::PlanShape,
                        Some(id),
                        format!(
                            "non-transfer node '{}' has no base-graph origin",
                            node.label
                        ),
                    ));
                    continue;
                }
            }
        };
        writers.entry(slot).or_default().push(id);
    }
    for (slot, ws) in &writers {
        for (i, &a) in ws.iter().enumerate() {
            for &b in &ws[i + 1..] {
                if anc.unordered(a, b) {
                    out.push(Diag::error(
                        Code::HostSlotRace,
                        Some(b),
                        format!(
                            "nodes {a} ('{}', device {}) and {b} ('{}', device {}) write \
                             host slot {slot:?} with no ordering between them",
                            view.graph.node(a).label,
                            view.device_of[a],
                            view.graph.node(b).label,
                            view.device_of[b],
                        ),
                    ));
                }
            }
        }
    }
    out.sort_by_key(|d| d.node);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rowir::task::Task;

    /// a (d0) → xfer (d1) → red (d1): the shape the lowering emits.
    fn clean_plan() -> (Graph, Vec<usize>, Vec<Option<NodeId>>) {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 8);
        let t = g.push_task(NodeKind::Transfer, "xfer.a.d1", vec![a], 8, 8, Task::Transfer);
        g.push(NodeKind::Barrier, "red", vec![t], 4);
        (g, vec![0, 1, 1], vec![Some(0), None, Some(1)])
    }

    fn diags(g: &Graph, dev: &[usize], orig: &[Option<NodeId>], devices: usize) -> Vec<Diag> {
        check(&ShardView {
            graph: g,
            device_of: dev,
            orig,
            devices,
        })
    }

    #[test]
    fn the_lowerings_shape_is_clean() {
        let (g, dev, orig) = clean_plan();
        assert!(diags(&g, &dev, &orig, 2).is_empty());
    }

    #[test]
    fn bare_cross_device_edge_is_sh002() {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 8);
        let red = g.push(NodeKind::Barrier, "red", vec![a], 4);
        let out = diags(&g, &[0, 1], &[Some(0), Some(1)], 2);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].code, Code::MissingTransfer);
        assert_eq!(out[0].node, Some(red));
    }

    #[test]
    fn same_device_copy_is_sh003() {
        let (g, mut dev, orig) = clean_plan();
        dev[1] = 0; // transfer staged on the source device...
        dev[2] = 0; // ...and consumed there: endpoints never differ
        let out = diags(&g, &dev, &orig, 2);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].code, Code::TransferEndpoint);
        assert_eq!(out[0].node, Some(1));
    }

    #[test]
    fn consumer_off_the_staging_device_is_sh003() {
        let (g, mut dev, orig) = clean_plan();
        dev[2] = 0; // red reads the d1-staged copy from d0
        let out = diags(&g, &dev, &orig, 2);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].code, Code::TransferEndpoint);
        assert_eq!(out[0].node, Some(2), "reported at the consumer");
    }

    #[test]
    fn unread_transfer_is_sh004() {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 8);
        let t = g.push_task(NodeKind::Transfer, "xfer.a.d1", vec![a], 8, 8, Task::Transfer);
        let out = diags(&g, &[0, 1], &[Some(0), None], 2);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].code, Code::DanglingTransfer);
        assert_eq!(out[0].node, Some(t));
    }

    #[test]
    fn unordered_duplicate_slot_writers_are_sh001() {
        let mut g = Graph::new();
        g.push(NodeKind::Row, "w0", vec![], 10);
        g.push(NodeKind::Row, "w1", vec![], 10);
        // both claim base slot 0, no edge between them
        let out = diags(&g, &[0, 1], &[Some(0), Some(0)], 2);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].code, Code::HostSlotRace);
        // an edge between them orders the writes: no race
        let mut g = Graph::new();
        let a = g.push(NodeKind::Row, "w0", vec![], 10);
        g.push(NodeKind::Row, "w1", vec![a], 10);
        assert!(diags(&g, &[0, 1], &[Some(0), Some(0)], 2)
            .iter()
            .all(|d| d.code == Code::MissingTransfer)); // only the bare edge
    }

    #[test]
    fn shape_errors_short_circuit() {
        let (g, dev, orig) = clean_plan();
        let out = diags(&g, &dev[..2], &orig, 2); // arity mismatch
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].code, Code::PlanShape);
        let out = diags(&g, &dev, &orig, 1); // device 1 outside topology
        assert!(out.iter().all(|d| d.code == Code::PlanShape));
        assert!(!out.is_empty());
    }

    #[test]
    fn ancestor_bitsets_cover_transitive_deps() {
        let mut g = Graph::new();
        let a = g.push(NodeKind::Row, "a", vec![], 1);
        let b = g.push(NodeKind::Row, "b", vec![a], 1);
        let c = g.push(NodeKind::Row, "c", vec![b], 1);
        let d = g.push(NodeKind::Row, "d", vec![], 1);
        let anc = Ancestors::of(&g);
        assert!(anc.is_ancestor(a, c), "transitive");
        assert!(!anc.is_ancestor(c, a));
        assert!(anc.unordered(c, d));
        assert!(!anc.unordered(a, c));
    }
}
