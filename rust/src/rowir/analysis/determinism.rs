//! The determinism lint — the bit-identity precondition, checked.
//!
//! Every driver's "bit-identical to serial" proof rests on four
//! structural facts about the graph (docs/ROWIR.md, docs/ANALYSIS.md):
//!
//! 1. **Reductions are barrier-confined** ([`Code::UnbarrieredReduction`]).
//!    f32 addition is not associative, so a node folding two or more row
//!    outputs is deterministic only if it is a [`NodeKind::Barrier`] —
//!    the one kind the executors dispatch after *all* deps finished, on
//!    one thread.  A Row/TpsRow folding row outputs would see them in
//!    scheduling order.  Transfer chains are looked through: a copy of a
//!    row output is still a row output.
//! 2. **Fold order is id order** ([`Code::FoldOrder`]).  Barrier handlers
//!    fold `deps` left-to-right; ids are the serial order, so deps must
//!    be strictly ascending — unsorted deps fold in the wrong order,
//!    duplicated deps fold an input twice.
//! 3. **Single writer per buffer** ([`Code::DoubleWriter`]).  Labels name
//!    handoff slots; two nodes with one label would race on the slot and
//!    make `find()` lie.
//! 4. **No cross-row write aliasing** ([`Code::CrossRowAlias`]).  Tasks
//!    name the work *and* the output row slab; two nodes carrying the
//!    same concrete task would write one slab twice in schedule order.
//!    `Opaque` (id-identified hand-built work) and `Transfer` (one copy
//!    per (producer, destination), distinguished by label/endpoint) are
//!    exempt — their identity is not their task.
//!
//! A violation reports the counterexample node, which is the whole point:
//! "this graph is non-deterministic *because of node 17*".

use std::collections::HashMap;

use super::super::graph::{Graph, NodeId, NodeKind};
use super::super::task::Task;
use super::{Code, Diag, Pass};

/// Resolve a dependency to its producing computation, looking through
/// Transfer copies (a transfer has exactly one dep; a malformed one is
/// reported by shardcheck, so stop rather than assume).
fn producer_kind(graph: &Graph, mut id: NodeId) -> NodeKind {
    loop {
        let node = graph.node(id);
        match (node.kind, node.deps.first()) {
            (NodeKind::Transfer, Some(&src)) => id = src,
            _ => return node.kind,
        }
    }
}

pub struct DeterminismPass;

impl Pass for DeterminismPass {
    fn name(&self) -> &'static str {
        "determinism"
    }

    fn run(&self, graph: &Graph, out: &mut Vec<Diag>) {
        let mut labels: HashMap<&str, NodeId> = HashMap::with_capacity(graph.len());
        let mut tasks: HashMap<Task, NodeId> = HashMap::with_capacity(graph.len());
        for (id, node) in graph.nodes().iter().enumerate() {
            // (2) fold order: strictly ascending deps
            if let Some(w) = node.deps.windows(2).find(|w| w[0] >= w[1]) {
                out.push(Diag::error(
                    Code::FoldOrder,
                    Some(id),
                    format!(
                        "node '{}' deps not strictly ascending ({} then {}) — a \
                         reduction here would fold out of serial order",
                        node.label, w[0], w[1]
                    ),
                ));
            }
            // (1) un-barriered reduction: ≥2 row-producing inputs outside
            // a barrier
            if node.kind != NodeKind::Barrier {
                let row_inputs = node
                    .deps
                    .iter()
                    .filter(|&&d| {
                        matches!(producer_kind(graph, d), NodeKind::Row | NodeKind::TpsRow)
                    })
                    .count();
                if row_inputs >= 2 {
                    out.push(Diag::error(
                        Code::UnbarrieredReduction,
                        Some(id),
                        format!(
                            "node '{}' ({:?}) folds {row_inputs} row outputs outside a \
                             barrier — f32 fold order would depend on scheduling",
                            node.label, node.kind
                        ),
                    ));
                }
            }
            // (3) single writer per buffer
            if let Some(&first) = labels.get(node.label.as_str()) {
                out.push(Diag::error(
                    Code::DoubleWriter,
                    Some(id),
                    format!(
                        "nodes {first} and {id} both write buffer '{}' — \
                         single-writer precondition broken",
                        node.label
                    ),
                ));
            } else {
                labels.insert(node.label.as_str(), id);
            }
            // (4) cross-row write aliasing
            if !matches!(node.task, Task::Opaque | Task::Transfer) {
                if let Some(&first) = tasks.get(&node.task) {
                    out.push(Diag::error(
                        Code::CrossRowAlias,
                        Some(id),
                        format!(
                            "nodes {first} and {id} both carry task {:?} — they \
                             would write the same row slab",
                            node.task
                        ),
                    ));
                } else {
                    tasks.insert(node.task, id);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(graph: &Graph) -> Vec<Diag> {
        let mut out = Vec::new();
        DeterminismPass.run(graph, &mut out);
        out
    }

    #[test]
    fn barrier_confined_reduction_is_accepted() {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 4);
        let b = g.push_out(NodeKind::Row, "b", vec![], 10, 4);
        g.push(NodeKind::Barrier, "red", vec![a, b], 2);
        assert!(run(&g).is_empty());
    }

    #[test]
    fn row_folding_two_rows_is_det001_with_the_counterexample() {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 4);
        let b = g.push_out(NodeKind::Row, "b", vec![], 10, 4);
        let bad = g.push(NodeKind::Row, "sneaky-reduce", vec![a, b], 2);
        let diags = run(&g);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code, Code::UnbarrieredReduction);
        assert_eq!(diags[0].node, Some(bad));
    }

    #[test]
    fn det001_sees_through_transfer_chains() {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 4);
        let b = g.push_out(NodeKind::Row, "b", vec![], 10, 4);
        let ta = g.push_task(NodeKind::Transfer, "xfer.a.d1", vec![a], 4, 4, Task::Transfer);
        let tb = g.push_task(NodeKind::Transfer, "xfer.b.d1", vec![b], 4, 4, Task::Transfer);
        // a barrier folding the copies is still fine...
        g.push(NodeKind::Barrier, "red", vec![ta, tb], 2);
        assert!(run(&g).is_empty());
        // ...a row folding them is still a hidden reduction
        let bad = g.push(NodeKind::Row, "sneaky", vec![ta, tb], 2);
        let diags = run(&g);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code, Code::UnbarrieredReduction);
        assert_eq!(diags[0].node, Some(bad));
    }

    #[test]
    fn one_row_input_plus_barriers_is_not_a_reduction() {
        // the BpRow shape: deps = [head (barrier), ck (barrier)]
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 4);
        let head = g.push_out(NodeKind::Barrier, "head", vec![a], 5, 4);
        let ck = g.push_out(NodeKind::Barrier, "ck", vec![a], 5, 4);
        g.push(NodeKind::Row, "bp", vec![head, ck], 3);
        assert!(run(&g).is_empty());
    }

    #[test]
    fn unsorted_deps_are_det002() {
        let mut g = Graph::new();
        let a = g.push(NodeKind::Row, "a", vec![], 1);
        let b = g.push(NodeKind::Row, "b", vec![], 1);
        g.push(NodeKind::Barrier, "red", vec![a, b], 1);
        g.nodes_mut()[2].deps = vec![b, a]; // corrupt past push's sort
        let diags = run(&g);
        assert!(diags.iter().any(|d| d.code == Code::FoldOrder && d.node == Some(2)));
        // duplicated deps too (fold an input twice)
        g.nodes_mut()[2].deps = vec![a, a];
        let diags = run(&g);
        assert!(diags.iter().any(|d| d.code == Code::FoldOrder && d.node == Some(2)));
    }

    #[test]
    fn duplicate_label_is_det003_naming_the_second_writer() {
        let mut g = Graph::new();
        g.push(NodeKind::Row, "slot", vec![], 1);
        let second = g.push(NodeKind::Row, "slot2", vec![], 1);
        g.nodes_mut()[second].label = "slot".into();
        let diags = run(&g);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code, Code::DoubleWriter);
        assert_eq!(diags[0].node, Some(second));
    }

    #[test]
    fn duplicate_concrete_task_is_det004_but_opaque_is_exempt() {
        let mut g = Graph::new();
        g.push_task(NodeKind::Row, "r0", vec![], 1, 0, Task::FpRow { seg: 0, row: 0 });
        let second =
            g.push_task(NodeKind::Row, "r0b", vec![], 1, 0, Task::FpRow { seg: 0, row: 0 });
        let diags = run(&g);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code, Code::CrossRowAlias);
        assert_eq!(diags[0].node, Some(second));
        // many Opaque nodes are the norm for hand-built graphs
        let mut g = Graph::new();
        g.push(NodeKind::Row, "a", vec![], 1);
        g.push(NodeKind::Row, "b", vec![], 1);
        assert!(run(&g).is_empty());
    }
}
