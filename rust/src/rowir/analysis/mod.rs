//! `rowir::analysis` — static verification and lint over a [`Graph`]
//! (docs/ANALYSIS.md).
//!
//! Every driver in this crate rests on one argument: loss and parameters
//! stay bit-identical to serial because all f32 reductions are confined
//! to barrier nodes folding their inputs in id (= serial) order, and
//! every buffer has a single writer.  Until now that invariant was
//! enforced *by construction* and re-proven empirically per change by the
//! test matrix.  This module makes it a **checked theorem** on the IR
//! itself:
//!
//! * [`determinism`] — the determinism lint: every reduction is
//!   barrier-confined, inputs are consumed in id order, one writer per
//!   buffer, no cross-row write aliasing.  A violation names the
//!   counterexample node.
//! * [`liveness`] — the def-use/liveness dataflow core (per-buffer last
//!   use, live-set sweep in ascending-id order) and the **static
//!   peak-memory bound**: [`liveness::static_peak`] satisfies
//!   `static_peak(g) >= interp replay peak` on every graph and is exact
//!   on fan graphs — an O(V+E) admission check that needs no replay.
//! * [`shardcheck`] — the shard-plan race/transfer checker over a
//!   device-assigned graph: single unordered writer per host slot, every
//!   cross-device edge carried by exactly one Transfer node with
//!   matching endpoints.
//!
//! Diagnostics are typed and machine-readable ([`Diag`]); rendering
//! reuses the crate's one JSON escaper (`util::json::escape`) and table
//! renderer (`metrics::Table`) — no bespoke serializers here.  The
//! passes run everywhere plans are born or rebuilt: `rowir::lower`,
//! `ShardPlan::lower` (and through it `ShardState::build`, the
//! fault-recovery repartition and `ShardState::recalibrate`), and the
//! `plan --lint` / `train --lint-strict` CLI paths.

pub mod determinism;
pub mod liveness;
pub mod shardcheck;

pub use liveness::{static_device_peaks, static_peak, Liveness};
pub use shardcheck::ShardView;

use crate::error::{Error, Result};
use crate::metrics::Table;
use crate::util::json::escape;

use super::graph::{Graph, NodeId};

/// Stable, machine-readable diagnostic codes.  The string forms are part
/// of the tool contract (`--lint-out` JSON, CI gates, docs/ANALYSIS.md)
/// — never renumber an existing code.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Code {
    /// `IR001` — forward/self dependency: the graph is not a DAG.
    NotADag,
    /// `DET001` — a node folds two or more row outputs outside a barrier
    /// (an un-barriered f32 reduction: fold order would depend on
    /// scheduling).
    UnbarrieredReduction,
    /// `DET002` — dependencies not strictly ascending: a barrier folding
    /// them would not fold in id (= serial) order, or would fold an input
    /// twice.
    FoldOrder,
    /// `DET003` — two nodes write the same buffer (duplicate label): the
    /// single-writer precondition is broken.
    DoubleWriter,
    /// `DET004` — two nodes carry the same non-transfer task, i.e. write
    /// the same row slab (cross-row write aliasing).
    CrossRowAlias,
    /// `LIV001` (warning) — a node parks output bytes no consumer ever
    /// reads; the bytes are dead weight in the byte plan.
    DeadOutput,
    /// `LIV002` — the liveness peak bound came out *below* a replay peak:
    /// the admission check would under-admit.  Synthesized by callers
    /// that have both numbers (`ShardPlan::analyze`, `plan --lint`).
    PeakBound,
    /// `SH001` — two concurrently-admissible writers of one host slot on
    /// different devices (a data race under the sharded executor).
    HostSlotRace,
    /// `SH002` — a cross-device edge with no Transfer node carrying it.
    MissingTransfer,
    /// `SH003` — a Transfer node whose endpoints don't match its
    /// placement (wrong arity, same-device copy, consumer off the
    /// destination device, or metadata disagreeing with the graph).
    TransferEndpoint,
    /// `SH004` — a Transfer node no consumer ever reads (dangling
    /// endpoint).
    DanglingTransfer,
    /// `SH005` — malformed plan shape (assignment/orig arity, device id
    /// outside the topology).
    PlanShape,
}

impl Code {
    pub fn as_str(&self) -> &'static str {
        match self {
            Code::NotADag => "IR001",
            Code::UnbarrieredReduction => "DET001",
            Code::FoldOrder => "DET002",
            Code::DoubleWriter => "DET003",
            Code::CrossRowAlias => "DET004",
            Code::DeadOutput => "LIV001",
            Code::PeakBound => "LIV002",
            Code::HostSlotRace => "SH001",
            Code::MissingTransfer => "SH002",
            Code::TransferEndpoint => "SH003",
            Code::DanglingTransfer => "SH004",
            Code::PlanShape => "SH005",
        }
    }
}

impl std::fmt::Display for Code {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// The plan must not run: a determinism/race/shape violation.
    Error,
    /// Suspicious but safe to run (e.g. dead parked bytes).
    Warning,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One typed, machine-readable diagnostic.  `node` is the counterexample
/// node when the finding anchors to one (the second writer, the
/// un-barriered reducer, the dangling transfer).
#[derive(Debug, Clone)]
pub struct Diag {
    pub code: Code,
    pub severity: Severity,
    pub node: Option<NodeId>,
    pub message: String,
}

impl Diag {
    pub fn error(code: Code, node: Option<NodeId>, message: impl Into<String>) -> Diag {
        Diag {
            code,
            severity: Severity::Error,
            node,
            message: message.into(),
        }
    }

    pub fn warning(code: Code, node: Option<NodeId>, message: impl Into<String>) -> Diag {
        Diag {
            code,
            severity: Severity::Warning,
            node,
            message: message.into(),
        }
    }
}

/// One analysis pass over a graph.  Passes append diagnostics; they never
/// mutate the graph (rewrites belong to a future optimizer pipeline, and
/// the lint must stay safe to run on anything).
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, graph: &Graph, out: &mut Vec<Diag>);
}

/// Structural precondition pass: the graph must be a DAG with ids in
/// topological order — everything later passes assume.  Mirrors
/// [`Graph::validate`]'s acyclicity rule but reports a typed [`Diag`]
/// instead of erroring on first sight, so a corrupted graph still yields
/// a counterexample node.
struct StructurePass;

impl Pass for StructurePass {
    fn name(&self) -> &'static str {
        "structure"
    }

    fn run(&self, graph: &Graph, out: &mut Vec<Diag>) {
        for (id, node) in graph.nodes().iter().enumerate() {
            if let Some(&bad) = node.deps.iter().find(|&&d| d >= id) {
                out.push(Diag::error(
                    Code::NotADag,
                    Some(id),
                    format!("node '{}' has forward/self dep {bad} — not a DAG", node.label),
                ));
            }
        }
    }
}

/// The default pass pipeline: structure gate, then the determinism lint,
/// then liveness.  Passes after a failing one are skipped — they assume
/// the earlier invariants, and the first counterexample is the one worth
/// reading.
pub struct Analyzer {
    passes: Vec<Box<dyn Pass>>,
}

impl Default for Analyzer {
    fn default() -> Self {
        Analyzer::new()
    }
}

impl Analyzer {
    pub fn new() -> Analyzer {
        Analyzer {
            passes: vec![
                Box::new(StructurePass),
                Box::new(determinism::DeterminismPass),
                Box::new(liveness::LivenessPass),
            ],
        }
    }

    pub fn with_pass(mut self, pass: Box<dyn Pass>) -> Analyzer {
        self.passes.push(pass);
        self
    }

    pub fn run(&self, graph: &Graph) -> Report {
        let mut diags = Vec::new();
        let mut ran = Vec::new();
        for pass in &self.passes {
            let before = diags.len();
            pass.run(graph, &mut diags);
            ran.push(pass.name());
            if diags[before..].iter().any(|d| d.severity == Severity::Error) {
                break; // later passes assume this one's invariants
            }
        }
        Report { diags, passes: ran }
    }
}

/// The outcome of an analysis run: every diagnostic, plus which passes
/// actually ran (a failing pass stops the pipeline).
#[derive(Debug, Clone)]
pub struct Report {
    pub diags: Vec<Diag>,
    pub passes: Vec<&'static str>,
}

impl Report {
    pub fn errors(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    pub fn has_errors(&self) -> bool {
        self.errors() > 0
    }

    /// No diagnostics at all — errors *or* warnings.
    pub fn is_clean(&self) -> bool {
        self.diags.is_empty()
    }

    /// First diagnostic carrying `code` (test/assertion convenience).
    pub fn find(&self, code: Code) -> Option<&Diag> {
        self.diags.iter().find(|d| d.code == code)
    }

    /// One-line verdict for logs and crash reports: "clean", or counts
    /// plus the distinct codes ("2 error(s), 1 warning(s): DET001 LIV001").
    pub fn verdict(&self) -> String {
        if self.is_clean() {
            return "clean".to_string();
        }
        let mut codes: Vec<&'static str> = self.diags.iter().map(|d| d.code.as_str()).collect();
        codes.sort_unstable();
        codes.dedup();
        format!(
            "{} error(s), {} warning(s): {}",
            self.errors(),
            self.warnings(),
            codes.join(" ")
        )
    }

    /// Render the diagnostics as a [`Table`] (what `plan --lint` prints).
    pub fn to_table(&self, title: impl Into<String>) -> Table {
        let mut t = Table::new(title, &["code", "severity", "node", "message"]);
        for d in &self.diags {
            t.row(vec![
                d.code.as_str().to_string(),
                d.severity.as_str().to_string(),
                d.node.map(|n| n.to_string()).unwrap_or_else(|| "-".into()),
                d.message.clone(),
            ]);
        }
        t
    }

    /// Machine-readable JSON (what `plan --lint --lint-out` writes per
    /// graph) — strings go through the crate's one escaper.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("{");
        let _ = write!(
            out,
            "\"errors\": {}, \"warnings\": {}, \"passes\": [",
            self.errors(),
            self.warnings()
        );
        for (i, p) in self.passes.iter().enumerate() {
            let _ = write!(out, "{}\"{}\"", if i > 0 { ", " } else { "" }, escape(p));
        }
        out.push_str("], \"diags\": [");
        for (i, d) in self.diags.iter().enumerate() {
            let node = d.node.map(|n| n.to_string()).unwrap_or_else(|| "null".into());
            let _ = write!(
                out,
                "{}{{\"code\": \"{}\", \"severity\": \"{}\", \"node\": {}, \"message\": \"{}\"}}",
                if i > 0 { ", " } else { "" },
                d.code.as_str(),
                d.severity.as_str(),
                node,
                escape(&d.message)
            );
        }
        out.push_str("]}");
        out
    }

    /// Gate: `Err(Error::Sched)` naming every error diagnostic.  Warnings
    /// pass.  What the plan-construction paths call before adopting a
    /// graph or plan.
    pub fn check(&self) -> Result<()> {
        if !self.has_errors() {
            return Ok(());
        }
        let msgs: Vec<String> = self
            .diags
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(|d| match d.node {
                Some(n) => format!("{} at node {n}: {}", d.code, d.message),
                None => format!("{}: {}", d.code, d.message),
            })
            .collect();
        Err(Error::Sched(format!("IR lint failed: {}", msgs.join("; "))))
    }
}

/// Run the default pass pipeline over a graph.
pub fn analyze(graph: &Graph) -> Report {
    Analyzer::new().run(graph)
}

/// [`analyze`] + [`Report::check`]: the gate `rowir::lower` (and every
/// other graph-construction boundary) runs before releasing a graph.
pub fn check_graph(graph: &Graph) -> Result<()> {
    analyze(graph).check()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rowir::graph::NodeKind;
    use crate::util::json::JsonValue;

    fn clean_fan() -> Graph {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 100, 40);
        let b = g.push_out(NodeKind::Row, "b", vec![], 100, 40);
        g.push(NodeKind::Barrier, "red", vec![a, b], 80);
        g
    }

    #[test]
    fn clean_graph_reports_clean() {
        let rep = analyze(&clean_fan());
        assert!(rep.is_clean(), "{:?}", rep.diags);
        assert_eq!(rep.verdict(), "clean");
        assert_eq!(rep.passes, vec!["structure", "determinism", "liveness"]);
        assert!(rep.check().is_ok());
        assert!(check_graph(&clean_fan()).is_ok());
    }

    #[test]
    fn corrupted_graph_yields_ir001_and_stops_the_pipeline() {
        let mut g = clean_fan();
        g.nodes_mut()[0].deps.push(0); // self-dep
        let rep = analyze(&g);
        let d = rep.find(Code::NotADag).expect("IR001 reported");
        assert_eq!(d.node, Some(0));
        assert_eq!(rep.passes, vec!["structure"], "later passes skipped");
        assert!(rep.check().is_err());
    }

    #[test]
    fn report_renders_table_and_valid_json() {
        let mut g = clean_fan();
        g.nodes_mut()[2].kind = NodeKind::Row; // un-barrier the reduction
        let rep = analyze(&g);
        assert!(rep.has_errors());
        let t = rep.to_table("lint");
        assert!(t.markdown().contains("DET001"), "{}", t.markdown());
        let json = format!("{{\"report\": {}}}", rep.to_json());
        JsonValue::parse(&json).expect("lint JSON parses");
        assert!(json.contains("\"code\": \"DET001\""), "{json}");
        assert!(rep.verdict().contains("DET001"), "{}", rep.verdict());
    }

    #[test]
    fn codes_are_stable_strings() {
        for (code, s) in [
            (Code::NotADag, "IR001"),
            (Code::UnbarrieredReduction, "DET001"),
            (Code::FoldOrder, "DET002"),
            (Code::DoubleWriter, "DET003"),
            (Code::CrossRowAlias, "DET004"),
            (Code::DeadOutput, "LIV001"),
            (Code::PeakBound, "LIV002"),
            (Code::HostSlotRace, "SH001"),
            (Code::MissingTransfer, "SH002"),
            (Code::TransferEndpoint, "SH003"),
            (Code::DanglingTransfer, "SH004"),
            (Code::PlanShape, "SH005"),
        ] {
            assert_eq!(code.as_str(), s);
            assert_eq!(code.to_string(), s);
        }
    }
}
