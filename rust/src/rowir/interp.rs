//! The serial interpreter — the reference driver of a [`RowProgram`].
//!
//! [`run`] executes every node in strictly ascending [`NodeId`] order on
//! the caller's thread.  Node ids are a topological order by construction
//! ([`super::Graph::push_task`]), so this *is* the serial schedule — the
//! one the pipelined and sharded executors are proven bit-identical to.
//! There is no separate hand-written serial step path anymore: "serial"
//! means "interpret the program in id order", which makes bit-identity to
//! serial a structural property of the other drivers rather than an
//! empirical one.
//!
//! ## Determinism contract (docs/ROWIR.md)
//!
//! * the runner is invoked exactly once per node, in ascending id order;
//! * a node runs only after all of its dependencies (all `< id`) ran;
//! * [`Task::Transfer`] nodes are executed by the interpreter itself (a
//!   no-op on one ledger) — the runner never sees them, matching the
//!   sharded executor's contract.
//!
//! ## Byte accounting
//!
//! The interpreter replays the same projected-byte ledger the admission
//! system bounds and `ShardPlan::replay_ledgers` predicts: while a node
//! runs, its `est_bytes` working set is held; after it finishes, its
//! `out_bytes` stay parked until its last consumer finishes.  The reported
//! [`InterpOutcome::peak_bytes`] therefore equals the single-device
//! `memory::sim` replay peak of the same graph **exactly** (property-
//! tested), and is the serial step's peak statistic.
//!
//! [`schedules`] is the allocation-schedule form of the same walk: it
//! derives per-device `memory::sim::Schedule`s from an IR walk, replacing
//! the bespoke replay code the shard planner used to carry.

use crate::error::Result;
use crate::memory::sim::Schedule;

use super::graph::{Graph, NodeId};
use super::task::Task;
use super::RowProgram;

/// Result of an interpreted run.
#[derive(Debug, Clone)]
pub struct InterpOutcome {
    /// Peak of the projected-byte ledger over the walk: running working
    /// sets + parked handoff bytes — the same currency the pipelined
    /// executors' admission ledgers bound, and exactly the single-device
    /// `memory::sim` replay peak of the graph.
    pub peak_bytes: u64,
    /// Ledger bytes still held after the walk — `0` for any well-formed
    /// run: every parked output is released by its last executed
    /// consumer, and a closure target (like a terminal node) parks
    /// nothing because its output is the walk's *result*, not interim
    /// residency.  Non-zero means a mis-built graph.
    pub final_bytes: u64,
    /// Nodes executed (transfers included; the whole program for
    /// [`run`], the dependency closure for [`run_closure`]).
    pub visited: usize,
}

/// Interpret the whole program: `runner(id, task)` for every node, in
/// strictly ascending id order, transfers executed by the interpreter.
pub fn run<F>(program: &RowProgram, runner: F) -> Result<InterpOutcome>
where
    F: FnMut(NodeId, Task) -> Result<()>,
{
    let include = vec![true; program.len()];
    run_subset(program, &include, runner)
}

/// Interpret only `target`'s dependency closure (its transitive deps plus
/// itself), in ascending id order — the forward-only entry point: for
/// 2PS the z^L barrier depends only on the chain, so the checkpoint half
/// of the program is skipped exactly as the hand-written forward path
/// used to.  The closure is dependency-closed by construction, so the
/// determinism contract holds unchanged on the subset.
pub fn run_closure<F>(program: &RowProgram, target: NodeId, runner: F) -> Result<InterpOutcome>
where
    F: FnMut(NodeId, Task) -> Result<()>,
{
    let graph = program.graph();
    let mut include = vec![false; graph.len()];
    if target < graph.len() {
        // deps are all `< id`, so one descending sweep marks the closure
        include[target] = true;
        for id in (0..=target).rev() {
            if include[id] {
                for &d in &graph.node(id).deps {
                    include[d] = true;
                }
            }
        }
    }
    run_subset(program, &include, runner)
}

/// The recompute set after a partial execution (docs/RESILIENCE.md):
/// which nodes must (re)run to produce `needed` outputs, given that
/// `materialized` nodes already finished and their outputs survive in
/// host slots.
///
/// A node is included when it is needed but not materialized; one
/// descending sweep (deps are all `< id`) then pulls in every
/// non-materialized dependency of an included node.  Materialized deps
/// stay excluded — they act as pre-satisfied inputs, which is exactly how
/// the executors treat excluded nodes of an include mask.  The result is
/// consumer-closed over any `materialized` mask produced by a real run
/// (a node cannot finish before its dependencies), so it is a valid
/// executor include mask.
pub fn recompute_closure(graph: &Graph, needed: &[bool], materialized: &[bool]) -> Vec<bool> {
    debug_assert_eq!(needed.len(), graph.len());
    debug_assert_eq!(materialized.len(), graph.len());
    let mut include: Vec<bool> = (0..graph.len())
        .map(|id| needed[id] && !materialized[id])
        .collect();
    for id in (0..graph.len()).rev() {
        if include[id] {
            for &d in &graph.node(id).deps {
                if !materialized[d] {
                    include[d] = true;
                }
            }
        }
    }
    include
}

/// The walk both entry points share: execute the `include`-marked nodes
/// (a dependency-closed set) in ascending id order, replaying the
/// projected-byte ledger.  Consumer counts are restricted to the subset,
/// so parked outputs release when their last *executed* consumer
/// finishes and a node with no in-subset consumers (a terminal, or the
/// closure target whose output is the walk's result) parks nothing —
/// every well-formed walk drains to `final_bytes == 0`.
fn run_subset<F>(program: &RowProgram, include: &[bool], mut runner: F) -> Result<InterpOutcome>
where
    F: FnMut(NodeId, Task) -> Result<()>,
{
    let graph = program.graph();
    // consumers within the subset only
    let mut left = vec![0usize; graph.len()];
    for (id, node) in graph.nodes().iter().enumerate() {
        if include[id] {
            for &d in &node.deps {
                left[d] += 1;
            }
        }
    }
    let mut cur = 0u64;
    let mut peak = 0u64;
    let mut visited = 0usize;
    for id in 0..graph.len() {
        if !include[id] {
            continue;
        }
        let node = graph.node(id);
        // working set held while the node runs
        cur += node.est_bytes;
        peak = peak.max(cur);
        if !node.task.is_transfer() {
            runner(id, node.task)?;
        }
        cur -= node.est_bytes;
        visited += 1;
        // outputs stay parked until the last in-subset consumer finishes
        if left[id] > 0 && node.out_bytes > 0 {
            cur += node.out_bytes;
            peak = peak.max(cur);
        }
        // this node was a consumer: release deps it was the last reader of
        for &d in &node.deps {
            left[d] -= 1;
            if left[d] == 0 && graph.node(d).out_bytes > 0 {
                cur -= graph.node(d).out_bytes;
            }
        }
    }
    Ok(InterpOutcome {
        peak_bytes: peak,
        final_bytes: cur,
        visited,
    })
}

/// Serial-order replay of a (possibly device-assigned) graph as one
/// allocation schedule per device: each node allocs its working set,
/// frees it at finish, then parks its output bytes until its last
/// consumer finishes.  `memory::sim::simulate` on each schedule yields
/// the exact per-device peak of a serial-order execution — the tight
/// admission budget (`ShardPlan::replay_ledgers` clamps it to device
/// memory).
///
/// `device_of[id]` assigns node `id` to a device lane `< devices`; pass
/// `&vec![0; graph.len()]` with `devices == 1` for the unsharded replay
/// (whose peak [`run`] reproduces without building schedules).
pub fn schedules(graph: &Graph, device_of: &[usize], devices: usize) -> Vec<Schedule> {
    let include = vec![true; graph.len()];
    schedules_subset(graph, device_of, devices, &include)
}

/// [`schedules`] restricted to an `include` mask — the recovery-phase
/// replay: only included nodes run (and park), and consumer counts are
/// subset-restricted exactly like the executors' bookkeeping, so the
/// schedules predict the peaks of a phase that runs just the unfinished
/// closure.  Excluded (materialized) nodes contribute nothing: their
/// outputs live in host slots, which the device-byte model never
/// charged for in the first place.
pub fn schedules_subset(
    graph: &Graph,
    device_of: &[usize],
    devices: usize,
    include: &[bool],
) -> Vec<Schedule> {
    debug_assert_eq!(device_of.len(), graph.len());
    debug_assert_eq!(include.len(), graph.len());
    let mut scheds: Vec<Schedule> = (0..devices).map(|_| Schedule::new()).collect();
    // consumers within the subset only
    let mut left = vec![0usize; graph.len()];
    for (id, node) in graph.nodes().iter().enumerate() {
        if include[id] {
            for &d in &node.deps {
                left[d] += 1;
            }
        }
    }
    for id in 0..graph.len() {
        if !include[id] {
            continue;
        }
        let node = graph.node(id);
        let s = &mut scheds[device_of[id]];
        s.mark(node.label.clone());
        let run = s.intern(format!("run.{}", node.label));
        s.alloc_id(run, node.est_bytes);
        s.free_id(run);
        if left[id] > 0 && node.out_bytes > 0 {
            s.alloc(format!("park.{}", node.label), node.out_bytes);
        }
        for &dep in &node.deps {
            if !include[dep] {
                continue; // materialized dep: never parked on a device
            }
            left[dep] -= 1;
            if left[dep] == 0 && graph.node(dep).out_bytes > 0 {
                let name = format!("park.{}", graph.node(dep).label);
                scheds[device_of[dep]].free(name);
            }
        }
    }
    scheds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::sim;
    use crate::rowir::graph::NodeKind;

    /// rows → barrier → rows → barrier with parked outputs (the lowered
    /// step-graph shape).
    fn fan_program(rows: usize) -> RowProgram {
        let mut g = Graph::new();
        let fp: Vec<NodeId> = (0..rows)
            .map(|r| g.push_out(NodeKind::Row, format!("fp{r}"), vec![], 100, 40))
            .collect();
        let head = g.push_out(NodeKind::Barrier, "head", fp, 100, 40);
        let bp: Vec<NodeId> = (0..rows)
            .map(|r| g.push_out(NodeKind::Row, format!("bp{r}"), vec![head], 100, 40))
            .collect();
        g.push(NodeKind::Barrier, "reduce", bp, 0);
        RowProgram::new(g).unwrap()
    }

    #[test]
    fn visits_every_node_in_ascending_id_order() {
        let prog = fan_program(4);
        let mut seen: Vec<NodeId> = Vec::new();
        let out = run(&prog, |id, _| {
            seen.push(id);
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, (0..prog.len()).collect::<Vec<_>>());
        assert_eq!(out.visited, prog.len());
        assert_eq!(out.final_bytes, 0, "a complete run drains the ledger");
    }

    #[test]
    fn peak_matches_the_sim_replay_exactly() {
        let prog = fan_program(3);
        let out = run(&prog, |_, _| Ok(())).unwrap();
        let sched = &schedules(prog.graph(), &vec![0; prog.len()], 1)[0];
        let rep = sim::simulate(sched).unwrap();
        assert_eq!(out.peak_bytes, rep.peak_bytes);
        assert_eq!(out.final_bytes, rep.final_bytes);
    }

    #[test]
    fn parked_outputs_count_until_the_last_consumer() {
        let mut g = Graph::new();
        // a's 100-byte output is consumed only by c, so it sits parked
        // while b runs
        let a = g.push_out(NodeKind::Row, "a", vec![], 100, 100);
        let b = g.push(NodeKind::Row, "b", vec![a], 10);
        g.push(NodeKind::Barrier, "c", vec![a, b], 5);
        let prog = RowProgram::new(g).unwrap();
        let out = run(&prog, |_, _| Ok(())).unwrap();
        // while b runs: parked(a)=100 + running(b)=10
        assert_eq!(out.peak_bytes, 110);
        assert_eq!(out.final_bytes, 0);
    }

    #[test]
    fn closure_run_stops_at_the_target_and_drains() {
        let prog = fan_program(2);
        // head's closure = {fp0, fp1, head}; the BP rows never run
        let head = prog.graph().find("head").unwrap();
        let mut seen = Vec::new();
        let out = run_closure(&prog, head, |id, _| {
            seen.push(id);
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, vec![0, 1, 2]);
        assert_eq!(out.visited, 3);
        // the target's output is the result, not interim residency — it
        // parks nothing (consumer counts are closure-restricted), and
        // the fp parks were released when head (their last in-closure
        // consumer) finished
        assert_eq!(out.final_bytes, 0);
    }

    /// The closure skips nodes the target does not depend on — the 2PS
    /// forward shape: a side fan (the checkpoint half) must not execute
    /// when the target chain never reads it.
    #[test]
    fn closure_skips_independent_side_nodes() {
        let mut g = Graph::new();
        let side = g.push_out(NodeKind::Row, "side", vec![], 50, 20);
        let _side_bar = g.push(NodeKind::Barrier, "side.bar", vec![side], 10);
        let a = g.push(NodeKind::Row, "chain0", vec![], 8);
        let b = g.push(NodeKind::Row, "chain1", vec![a], 8);
        let zl = g.push(NodeKind::Barrier, "zl", vec![a, b], 4);
        let prog = RowProgram::new(g).unwrap();
        let mut seen = Vec::new();
        let out = run_closure(&prog, zl, |id, _| {
            seen.push(id);
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, vec![a, b, zl], "side fan skipped entirely");
        assert_eq!(out.visited, 3);
        assert_eq!(out.final_bytes, 0);
    }

    #[test]
    fn transfers_are_executed_by_the_interpreter_not_the_runner() {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 10);
        let t = g.push_task(NodeKind::Transfer, "xfer.a.d1", vec![a], 10, 10, Task::Transfer);
        g.push(NodeKind::Barrier, "red", vec![t], 5);
        let prog = RowProgram::new(g).unwrap();
        let mut seen = Vec::new();
        run(&prog, |id, task| {
            assert!(!task.is_transfer());
            seen.push(id);
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, vec![0, 2], "the transfer never reaches the runner");
    }

    #[test]
    fn runner_error_stops_the_walk() {
        let prog = fan_program(2);
        let mut ran = 0usize;
        let res = run(&prog, |id, _| {
            ran += 1;
            if id == 1 {
                Err(crate::error::Error::Runtime("boom".into()))
            } else {
                Ok(())
            }
        });
        assert!(res.is_err());
        assert_eq!(ran, 2, "nodes after the failure never run");
    }

    #[test]
    fn per_device_schedules_split_by_assignment() {
        let prog = fan_program(2);
        // fp0 on 0, fp1 on 1, rest on 0
        let mut dev = vec![0usize; prog.len()];
        dev[1] = 1;
        let scheds = schedules(prog.graph(), &dev, 2);
        assert_eq!(scheds.len(), 2);
        for s in &scheds {
            assert_eq!(sim::simulate(s).unwrap().final_bytes, 0, "drains");
        }
        // device 1 holds only fp1: run 100 (its park is freed on device 1
        // when the head — device 0 — consumes it)
        assert_eq!(sim::simulate(&scheds[1]).unwrap().peak_bytes, 100);
    }

    #[test]
    fn recompute_closure_skips_materialized_work() {
        let prog = fan_program(2);
        let g = prog.graph();
        // the fps finished before the loss; everything is still needed
        let mut materialized = vec![false; g.len()];
        materialized[g.find("fp0").unwrap()] = true;
        materialized[g.find("fp1").unwrap()] = true;
        let needed = vec![true; g.len()];
        let inc = recompute_closure(g, &needed, &materialized);
        assert!(!inc[g.find("fp0").unwrap()], "materialized rows are kept");
        assert!(!inc[g.find("fp1").unwrap()]);
        assert!(inc[g.find("head").unwrap()], "unfinished consumers rerun");
        assert!(inc[g.find("reduce").unwrap()]);
        // nothing materialized: the closure is the whole program
        let all = recompute_closure(g, &needed, &vec![false; g.len()]);
        assert!(all.iter().all(|&b| b));
        // everything materialized: nothing to do
        let none = recompute_closure(g, &needed, &vec![true; g.len()]);
        assert!(none.iter().all(|&b| !b));
    }

    /// A needed node whose dependency was *not* materialized must pull
    /// that dependency (transitively) back in.
    #[test]
    fn recompute_closure_pulls_unmaterialized_deps_transitively() {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 10);
        let b = g.push_out(NodeKind::Row, "b", vec![a], 10, 10);
        let c = g.push(NodeKind::Barrier, "c", vec![b], 5);
        let mut needed = vec![false; g.len()];
        needed[c] = true;
        let inc = recompute_closure(&g, &needed, &vec![false; g.len()]);
        assert!(inc[a] && inc[b] && inc[c], "transitive deps pulled in");
    }

    #[test]
    fn subset_schedules_match_the_executed_subset() {
        let prog = fan_program(2);
        let g = prog.graph();
        // recovery shape: fps materialized, the rest reruns on one device
        let mut include = vec![true; g.len()];
        include[g.find("fp0").unwrap()] = false;
        include[g.find("fp1").unwrap()] = false;
        let scheds = schedules_subset(g, &vec![0; g.len()], 1, &include);
        let rep = sim::simulate(&scheds[0]).unwrap();
        assert_eq!(rep.final_bytes, 0, "the subset replay drains");
        // head runs with no fp parks charged (they live in host slots):
        // peak is bp1 running (100) over head's + bp0's parks (40 + 40)
        assert_eq!(rep.peak_bytes, 180);
        // the all-true mask reproduces the unrestricted replay exactly
        let full = schedules(g, &vec![0; g.len()], 1);
        let full_subset =
            schedules_subset(g, &vec![0; g.len()], 1, &vec![true; g.len()]);
        assert_eq!(
            sim::simulate(&full[0]).unwrap().peak_bytes,
            sim::simulate(&full_subset[0]).unwrap().peak_bytes
        );
    }
}
