//! Transfer coalescing/dedup: two [`Task::Transfer`] nodes copying the
//! same producer's payload to the same destination device are one copy
//! doing double duty — the sharded lowering dedups the transfers *it*
//! inserts, but remat clones, hand-built graphs and composed rewrites
//! can reintroduce duplicates across fans.
//!
//! A merge keeps the lowest-id transfer of each (producer, device)
//! group, redirects every consumer of a duplicate onto it (the kept id
//! is always lower, so deps stay backward), takes the max payload and
//! deletes the duplicate.  Each merge is priced on a trial copy first
//! and applied **only when no device's static peak rises**: folding two
//! copies into one extends the kept copy's park to the later consumer,
//! which is usually a win (one payload resident instead of two) but can
//! lose when the copies' live spans were disjoint — and an unconditional
//! merge could silently undo a remat split, ping-ponging the fixpoint.
//! The peak gate breaks that cycle structurally.  A rejected merge is
//! not a rewrite, so re-examining it on the next fixpoint iteration
//! (and rejecting it again) still quiesces.
//!
//! Bit-identity: a transfer's value *is* its producer's payload, so a
//! consumer reading the kept copy reads the same bytes — and handlers
//! read host slots keyed by task identity, never graph deps, so the
//! rewire cannot reorder any reduction.

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use crate::rowir::task::Task;

use super::{OptContext, WorkGraph};

/// Merge duplicate same-(producer, device) transfers.  Returns the
/// number of transfers deleted; modeled link seconds saved accumulate
/// into `saved_s`.
pub(crate) fn run(wg: &mut WorkGraph, cx: &OptContext, saved_s: &mut f64) -> usize {
    // Snapshot the duplicate pairs as (keep, dup) *labels*: labels are
    // stable identities across the retain a merge performs, node ids are
    // not.  Each pair is evaluated exactly once per pass run.
    let mut pairs: Vec<(String, String)> = Vec::new();
    {
        let mut first: HashMap<(usize, usize), usize> = HashMap::new();
        for (id, node) in wg.nodes.iter().enumerate() {
            if node.task != Task::Transfer || node.deps.len() != 1 {
                continue;
            }
            match first.entry((node.deps[0], node.device)) {
                Entry::Vacant(e) => {
                    e.insert(id);
                }
                Entry::Occupied(e) => pairs.push((
                    wg.nodes[*e.get()].label.clone(),
                    node.label.clone(),
                )),
            }
        }
    }
    let mut rewrites = 0usize;
    for (keep_label, dup_label) in pairs {
        let find = |l: &str| wg.nodes.iter().position(|n| n.label == l);
        let (Some(keep), Some(dup)) = (find(&keep_label), find(&dup_label)) else {
            continue;
        };
        let before = wg.device_peaks();
        let mut trial = wg.clone();
        merge(&mut trial, keep, dup);
        let after = trial.device_peaks();
        if (0..wg.devices).all(|d| after[d] <= before[d]) {
            *saved_s += cx.cost.transfer_seconds(wg.nodes[dup].est_bytes);
            *wg = trial;
            rewrites += 1;
        }
    }
    rewrites
}

/// Redirect every consumer of `dup` onto `keep` (same producer, same
/// device, `keep < dup`), merge the payload, delete `dup`.
fn merge(wg: &mut WorkGraph, keep: usize, dup: usize) {
    debug_assert!(keep < dup);
    let est = wg.nodes[keep].est_bytes.max(wg.nodes[dup].est_bytes);
    let out = wg.nodes[keep].out_bytes.max(wg.nodes[dup].out_bytes);
    wg.nodes[keep].est_bytes = est;
    wg.nodes[keep].out_bytes = out;
    for node in wg.nodes.iter_mut() {
        if node.deps.contains(&dup) {
            for d in node.deps.iter_mut() {
                if *d == dup {
                    *d = keep;
                }
            }
            node.deps.sort_unstable();
            node.deps.dedup();
        }
    }
    let mut mask = vec![true; wg.nodes.len()];
    mask[dup] = false;
    wg.retain(&mask);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rowir::graph::{Graph, NodeKind};

    /// producer → two identical copies to the same device → two readers.
    fn dup_graph() -> Graph {
        let mut g = Graph::new();
        let p = g.push_out(NodeKind::Row, "p", vec![], 100, 40);
        let t1 = g.push_task(NodeKind::Transfer, "xfer.p.d1", vec![p], 40, 40, Task::Transfer);
        let t2 = g.push_task(NodeKind::Transfer, "xfer.p.d1.b", vec![p], 40, 40, Task::Transfer);
        let c1 = g.push(NodeKind::Row, "c1", vec![t1], 10);
        g.push(NodeKind::Barrier, "red", vec![t2, c1], 5);
        g
    }

    #[test]
    fn merges_duplicates_and_rewires_consumers() {
        let g = dup_graph();
        let dev = vec![0usize, 1, 1, 1, 1];
        let mut wg = WorkGraph::from_graph(&g, Some(&dev), 2);
        let before = wg.device_peaks();
        let cx = OptContext::serial();
        let mut saved = 0.0;
        assert_eq!(run(&mut wg, &cx, &mut saved), 1);
        assert!(saved > 0.0, "the deleted copy's link time is credited");
        assert_eq!(wg.nodes.len(), g.len() - 1);
        let after = wg.device_peaks();
        for d in 0..2 {
            assert!(after[d] <= before[d], "device {d}");
        }
        // both readers now read the surviving transfer
        let (g2, _, _) = wg.to_graph().unwrap();
        let t = g2.find("xfer.p.d1").unwrap();
        assert!(g2.node(g2.find("c1").unwrap()).deps.contains(&t));
        assert!(g2.node(g2.find("red").unwrap()).deps.contains(&t));
        assert_eq!(run(&mut wg, &cx, &mut saved), 0, "idempotent at fixpoint");
    }

    #[test]
    fn different_devices_do_not_merge() {
        let g = dup_graph();
        // the two copies land on different devices: distinct payloads
        let dev = vec![0usize, 1, 2, 1, 2];
        let mut wg = WorkGraph::from_graph(&g, Some(&dev), 3);
        let cx = OptContext::serial();
        let mut saved = 0.0;
        assert_eq!(run(&mut wg, &cx, &mut saved), 0);
        assert_eq!(wg.nodes.len(), g.len());
    }
}
