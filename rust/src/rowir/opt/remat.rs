//! Budget-driven rematerialization (Chen et al., *Training Deep Nets
//! with Sublinear Memory Cost*, on the row IR): convert a retain-edge —
//! a parked `out_bytes` grant held from its producer to a distant last
//! consumer — into a recompute subgraph cloned immediately before that
//! consumer, trading modeled recompute seconds for resident bytes.
//!
//! ## Victim selection
//!
//! Every node `v` with a parked output and at least one consumer is a
//! candidate; `L = last_use(v)` is where its park dies.  The recompute
//! subgraph is the [`recompute_closure`](crate::rowir::interp::recompute_closure)
//! of `{v}` under the materialization rule *"a dependency is available
//! at `L` iff its own park is still alive there"* (`out_bytes > 0` and
//! `last_use >= L`) — anything not available is pulled into the closure
//! and cloned too.  Candidates are ranked by **bytes freed per modeled
//! recompute second** (`CostModel::remat_score` over
//! [`CostModel::recompute_seconds`]) and tried greedily.
//!
//! ## The pure-clone constraint
//!
//! A closure containing any task other than `Opaque`/`Transfer` is
//! rejected outright.  This is principled, not a limitation: DET004
//! makes a duplicated concrete task an analyzer *error* (two nodes
//! would race on the same host slot), and the executors' write-once
//! slots make re-running a concrete handler unsafe.  Rematerialization
//! therefore fires on pure synthetic subgraphs and on transfers (a
//! re-fetch of a producer whose park is still alive) — and is
//! structurally a no-op on the fully-concrete serial mode programs,
//! which is exactly what keeps the executed bit-identity matrix safe.
//!
//! ## Acceptance and termination
//!
//! A rewrite is applied only when a trial evaluation shows **no
//! device's static peak rises** and the objective
//! `Σ_d max(peak_d − target_d, 0)` (targets = the budgets, or 0 when
//! none were given) **strictly decreases**.  The objective is a `u64`
//! strictly decreasing across accepted rewrites, so the pass — and with
//! it the fixpoint — terminates.  The pass stops early the moment the
//! budgets are satisfied; declaring the budgets infeasible after the
//! fixpoint is the pipeline's job.

use crate::error::Result;
use crate::rowir::task::Task;

use super::{OptContext, WorkGraph, WorkNode};

/// Accumulated remat statistics, folded into the pipeline's `OptReport`.
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct RematStats {
    pub bytes_freed: u64,
    pub recompute_seconds_added: f64,
}

/// Greedy budget-driven rematerialization.  Returns the number of
/// rewrites applied.
pub(crate) fn run(wg: &mut WorkGraph, cx: &OptContext, stats: &mut RematStats) -> Result<usize> {
    let devices = wg.devices;
    let targets: Vec<u64> = match &cx.budgets {
        Some(b) => b.clone(),
        None => vec![0; devices],
    };
    let objective = |peaks: &[u64]| -> u64 {
        peaks
            .iter()
            .zip(&targets)
            .map(|(&p, &t)| p.saturating_sub(t))
            .sum()
    };
    let mut rewrites = 0usize;
    loop {
        let peaks = wg.device_peaks();
        let obj = objective(&peaks);
        if obj == 0 {
            break; // the budgets are satisfied — nothing left to free
        }
        let last_use = wg.last_use();
        // rank candidates: bytes freed per modeled recompute second
        let mut cands: Vec<(f64, usize, usize, Vec<usize>, f64)> = Vec::new();
        for v in 0..wg.nodes.len() {
            if wg.nodes[v].out_bytes == 0 {
                continue;
            }
            let Some(l) = last_use[v] else { continue };
            let Some(closure) = pure_closure(wg, v, l, &last_use) else {
                continue;
            };
            let items: Vec<(usize, u64, bool)> = closure
                .iter()
                .map(|&c| {
                    let n = &wg.nodes[c];
                    (n.device, n.est_bytes, n.task.is_transfer())
                })
                .collect();
            let secs = cx.cost.recompute_seconds(&items);
            let score = cx.cost.remat_score(wg.nodes[v].out_bytes, secs);
            cands.push((score, v, l, closure, secs));
        }
        cands.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        let mut applied = false;
        for (_, v, l, closure, secs) in cands {
            let mut trial = wg.clone();
            apply(&mut trial, v, l, &closure);
            let tpeaks = trial.device_peaks();
            if (0..devices).all(|d| tpeaks[d] <= peaks[d]) && objective(&tpeaks) < obj {
                stats.bytes_freed += wg.nodes[v].out_bytes;
                stats.recompute_seconds_added += secs;
                *wg = trial;
                rewrites += 1;
                applied = true;
                break;
            }
        }
        if !applied {
            break; // no profitable victim remains
        }
    }
    Ok(rewrites)
}

/// The recompute closure of `{v}` as seen from just before node `l`,
/// under the park-alive materialization rule — `None` when any closure
/// node carries a concrete task (cloning it would duplicate observable
/// work; see the module docs).
fn pure_closure(
    wg: &WorkGraph,
    v: usize,
    l: usize,
    last_use: &[Option<usize>],
) -> Option<Vec<usize>> {
    let mut include = vec![false; v + 1];
    include[v] = true;
    for id in (0..=v).rev() {
        if !include[id] {
            continue;
        }
        if !matches!(wg.nodes[id].task, Task::Opaque | Task::Transfer) {
            return None;
        }
        for &d in &wg.nodes[id].deps {
            // a dep is materialized at `l` iff its park is still alive
            // there; v itself is what we are recomputing
            let alive_at_l =
                wg.nodes[d].out_bytes > 0 && last_use[d].is_some_and(|lu| lu >= l);
            if !alive_at_l {
                include[d] = true;
            }
        }
    }
    Some((0..=v).filter(|&i| include[i]).collect())
}

/// Clone `closure` (ascending ids) immediately before `l`, rewire `l`'s
/// dependency on `v` onto the clone of `v`, leave everything else
/// untouched.  Clone-internal deps point at clones; external deps at
/// their (park-alive) originals, all `< l`, so ids stay topological.
fn apply(wg: &mut WorkGraph, v: usize, l: usize, closure: &[usize]) {
    use std::collections::HashMap;
    let n = wg.nodes.len();
    let m = closure.len();
    let k = wg.next_fresh();
    let mut clone_of: HashMap<usize, usize> = HashMap::with_capacity(m);
    let mut nodes: Vec<WorkNode> = Vec::with_capacity(n + m);
    nodes.extend(wg.nodes[..l].iter().cloned());
    for (i, &c) in closure.iter().enumerate() {
        let src = &wg.nodes[c];
        let mut deps: Vec<usize> = src
            .deps
            .iter()
            .map(|d| clone_of.get(d).copied().unwrap_or(*d))
            .collect();
        deps.sort_unstable();
        deps.dedup();
        nodes.push(WorkNode {
            kind: src.kind,
            label: format!("remat.{k}.{}", src.label),
            deps,
            task: src.task,
            est_bytes: src.est_bytes,
            out_bytes: src.out_bytes,
            device: src.device,
            orig: None,
        });
        clone_of.insert(c, l + i);
    }
    for id in l..n {
        let mut node = wg.nodes[id].clone();
        for d in node.deps.iter_mut() {
            if *d >= l {
                *d += m; // the shift is monotone: sortedness survives
            }
        }
        if id == l {
            for d in node.deps.iter_mut() {
                if *d == v {
                    *d = clone_of[&v];
                }
            }
            node.deps.sort_unstable();
            node.deps.dedup();
        }
        nodes.push(node);
    }
    wg.nodes = nodes;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rowir::analysis;
    use crate::rowir::graph::{Graph, NodeKind};

    /// The canonical retain-edge: `a` parks 100 B across unrelated work
    /// `b` (which never reads `a`), and only the distant `c` consumes it.
    /// Peak 110 = park(a) + run(b).  Rematerializing `a` just before `c`
    /// drops the park across `b`: peak 105 = run(a') + run(c).
    fn retain_edge() -> Graph {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 100, 100);
        let b = g.push(NodeKind::Row, "b", vec![], 10);
        g.push(NodeKind::Barrier, "c", vec![a, b], 5);
        g
    }

    #[test]
    fn frees_the_retain_edge_and_stays_valid() {
        let g = retain_edge();
        assert_eq!(analysis::static_peak(&g), 110);
        let mut wg = WorkGraph::from_graph(&g, None, 1);
        let cx = OptContext::serial();
        let mut stats = RematStats::default();
        let n = run(&mut wg, &cx, &mut stats).unwrap();
        assert!(n >= 1, "the retain edge is a victim");
        assert!(stats.bytes_freed >= 100);
        assert!(stats.recompute_seconds_added > 0.0);
        let (g2, _, orig) = wg.to_graph().unwrap();
        assert!(analysis::static_peak(&g2) < 110, "peak strictly dropped");
        assert!(!analysis::analyze(&g2).has_errors());
        // the clone carries provenance None and a remat label
        let clone = g2.find("remat.0.a").expect("clone exists");
        assert_eq!(orig[clone], None);
        // c now reads the clone, not the original
        let c = g2.find("c").unwrap();
        assert!(g2.node(c).deps.contains(&clone));
    }

    #[test]
    fn concrete_closures_are_never_cloned() {
        let mut g = Graph::new();
        let a = g.push_task(
            NodeKind::Row,
            "a",
            vec![],
            100,
            100,
            Task::FpRow { seg: 0, row: 0 },
        );
        let b = g.push(NodeKind::Row, "b", vec![a], 10);
        g.push(NodeKind::Barrier, "c", vec![a, b], 5);
        let mut wg = WorkGraph::from_graph(&g, None, 1);
        let cx = OptContext::serial();
        let mut stats = RematStats::default();
        assert_eq!(run(&mut wg, &cx, &mut stats).unwrap(), 0);
        assert_eq!(wg.nodes.len(), g.len(), "nothing rewritten");
    }

    #[test]
    fn budget_satisfaction_stops_the_pass_early() {
        let g = retain_edge();
        let mut wg = WorkGraph::from_graph(&g, None, 1);
        // 110 already fits a 110-byte budget: zero objective, zero work
        let cx = OptContext::serial().with_budgets(vec![110]);
        let mut stats = RematStats::default();
        assert_eq!(run(&mut wg, &cx, &mut stats).unwrap(), 0);
        assert_eq!(wg.nodes.len(), g.len());
    }
}
