//! The fixpoint driver: run the passes until a full iteration performs
//! zero rewrites, re-verifying the graph after every pass that touched
//! it.
//!
//! ## Levels
//!
//! * `0` — identity: the input graph comes back untouched (with an
//!   identity provenance map), so `--opt-level 0` is a true baseline.
//! * `1` — [`dce`] + [`coalesce`]: deletions and merges only, never a
//!   new node.
//! * `2` — adds [`remat`], the budget-driven retain→recompute rewrite.
//!
//! ## Termination
//!
//! Every accepted rewrite strictly decreases the lexicographic measure
//! *(objective, node count)* where the objective is
//! `Σ_d max(peak_d − target_d, 0)`: no pass ever raises any device's
//! static peak (verified, not assumed), remat rewrites strictly
//! decrease the objective, and dce/coalesce rewrites strictly decrease
//! the node count at a non-increased objective — only remat adds nodes,
//! and only with a strictly lower objective, so no state can recur.
//! [`MAX_ITERS`] is a defensive backstop: exceeding it is a typed error
//! (`Error::Sched`), never a silent partial result.
//!
//! ## Verification
//!
//! After each rewriting pass the pipeline checks (a) no device's
//! [`static_device_peaks`](crate::rowir::analysis::static_device_peaks)
//! bound rose, (b) the rebuilt graph passes [`Graph::validate`], and
//! (c) the PR 9 analyzer reports zero errors.  The final graph must
//! additionally keep every concrete task of the input program alive
//! ([`optimize`]'s semantic floor) — the optimizer may clone pure
//! nodes and delete debris, but it may never drop observable work.

use crate::error::{Error, Result};
use crate::metrics::Table;
use crate::rowir::analysis;
use crate::rowir::graph::{Graph, NodeId};
use crate::rowir::task::Task;
use crate::rowir::RowProgram;

use super::{coalesce, dce, remat, OptContext, WorkGraph};

/// Defensive iteration cap — see the module docs.  Real programs
/// quiesce in 2 (one working iteration + one proving quiescence).
pub const MAX_ITERS: usize = 12;

/// One pass invocation inside the fixpoint loop.
#[derive(Debug, Clone)]
pub struct PassOutcome {
    pub pass: &'static str,
    /// 0-based fixpoint iteration this invocation ran in.
    pub iteration: usize,
    pub rewrites: usize,
    pub peak_before: Vec<u64>,
    pub peak_after: Vec<u64>,
}

/// What the optimizer did — per-pass rewrite counts plus the headline
/// byte/seconds accounting.  Folded into `obs::RunReport` and printed
/// by `plan --optimize`.
#[derive(Debug, Clone)]
pub struct OptReport {
    pub level: u8,
    /// Fixpoint iterations run (the last one performs zero rewrites).
    pub iterations: usize,
    pub passes: Vec<PassOutcome>,
    pub peak_before: Vec<u64>,
    pub peak_after: Vec<u64>,
    pub nodes_before: usize,
    pub nodes_after: usize,
    /// Parked bytes converted to recompute by [`remat`].
    pub bytes_freed: u64,
    /// Modeled seconds the remat recompute subgraphs add per step.
    pub recompute_seconds_added: f64,
    /// Modeled link seconds the coalesced transfers save per step.
    pub transfer_seconds_saved: f64,
}

impl OptReport {
    /// Total rewrites across every pass invocation.
    pub fn rewrites(&self) -> usize {
        self.passes.iter().map(|p| p.rewrites).sum()
    }

    /// Sum of per-device static peaks before optimization.
    pub fn total_peak_before(&self) -> u64 {
        self.peak_before.iter().sum()
    }

    /// Sum of per-device static peaks after optimization.
    pub fn total_peak_after(&self) -> u64 {
        self.peak_after.iter().sum()
    }

    /// Per-pass rewrite table (what `plan --optimize` prints per mode).
    pub fn to_table(&self, title: impl Into<String>) -> Table {
        let mut t = Table::new(
            title,
            &["pass", "iter", "rewrites", "peak before (B)", "peak after (B)"],
        );
        for p in &self.passes {
            if p.rewrites == 0 {
                continue; // quiescence proofs are noise in the table
            }
            t.row(vec![
                p.pass.to_string(),
                p.iteration.to_string(),
                p.rewrites.to_string(),
                p.peak_before.iter().sum::<u64>().to_string(),
                p.peak_after.iter().sum::<u64>().to_string(),
            ]);
        }
        t.row(vec![
            "total".into(),
            self.iterations.to_string(),
            self.rewrites().to_string(),
            self.total_peak_before().to_string(),
            self.total_peak_after().to_string(),
        ]);
        t
    }

    /// Deterministic JSON object (embedded by `RunReport::to_json` and
    /// the `--dump-ir --optimized` artifact).
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v}")
            } else {
                "null".into()
            }
        }
        fn u64s(v: &[u64]) -> String {
            let items: Vec<String> = v.iter().map(|p| p.to_string()).collect();
            format!("[{}]", items.join(", "))
        }
        let mut o = String::from("{");
        let _ = write!(o, "\"level\": {}", self.level);
        let _ = write!(o, ", \"iterations\": {}", self.iterations);
        let _ = write!(o, ", \"rewrites\": {}", self.rewrites());
        let _ = write!(o, ", \"nodes_before\": {}", self.nodes_before);
        let _ = write!(o, ", \"nodes_after\": {}", self.nodes_after);
        let _ = write!(o, ", \"peak_before\": {}", u64s(&self.peak_before));
        let _ = write!(o, ", \"peak_after\": {}", u64s(&self.peak_after));
        let _ = write!(o, ", \"bytes_freed\": {}", self.bytes_freed);
        let _ = write!(
            o,
            ", \"recompute_seconds_added\": {}",
            num(self.recompute_seconds_added)
        );
        let _ = write!(
            o,
            ", \"transfer_seconds_saved\": {}",
            num(self.transfer_seconds_saved)
        );
        o.push_str(", \"passes\": [");
        for (i, p) in self.passes.iter().enumerate() {
            if i > 0 {
                o.push_str(", ");
            }
            let _ = write!(
                o,
                "{{\"pass\": \"{}\", \"iteration\": {}, \"rewrites\": {}}}",
                p.pass, p.iteration, p.rewrites
            );
        }
        o.push_str("]}");
        o
    }
}

/// The result of [`optimize_graph`]: the rewritten graph, its device
/// assignment, the input-graph provenance of every surviving node
/// (`None` for remat clones), and the report.
#[derive(Debug, Clone)]
pub struct OptOutcome {
    pub graph: Graph,
    pub device_of: Vec<usize>,
    pub orig_of: Vec<Option<NodeId>>,
    pub report: OptReport,
}

/// Optimize a bare graph under `cx`.  This is the engine `ShardPlan::optimize`
/// drives with a multi-device context; serial callers use [`optimize`].
pub fn optimize_graph(graph: &Graph, level: u8, cx: &OptContext) -> Result<OptOutcome> {
    graph.validate()?;
    let level = level.min(2);
    if let Some(dev) = &cx.device_of {
        if dev.len() != graph.len() {
            return Err(Error::Sched(format!(
                "optimizer device map arity {} != graph len {}",
                dev.len(),
                graph.len()
            )));
        }
        if let Some(&bad) = dev.iter().find(|&&d| d >= cx.devices) {
            return Err(Error::Sched(format!(
                "optimizer device map names device {bad} outside 0..{}",
                cx.devices
            )));
        }
    }
    if let Some(b) = &cx.budgets {
        if b.len() != cx.devices {
            return Err(Error::Sched(format!(
                "optimizer budget arity {} != device count {}",
                b.len(),
                cx.devices
            )));
        }
    }
    let mut wg = WorkGraph::from_graph(graph, cx.device_of.as_deref(), cx.devices);
    let peak_before = wg.device_peaks();
    let mut report = OptReport {
        level,
        iterations: 0,
        passes: Vec::new(),
        peak_before: peak_before.clone(),
        peak_after: peak_before.clone(),
        nodes_before: wg.nodes.len(),
        nodes_after: wg.nodes.len(),
        bytes_freed: 0,
        recompute_seconds_added: 0.0,
        transfer_seconds_saved: 0.0,
    };
    if level == 0 {
        let (g, device_of, orig_of) = wg.to_graph()?;
        return Ok(OptOutcome {
            graph: g,
            device_of,
            orig_of,
            report,
        });
    }
    let mut peaks = peak_before;
    let mut quiesced = false;
    for iteration in 0..MAX_ITERS {
        report.iterations = iteration + 1;
        let mut total = 0usize;

        let before = peaks.clone();
        let n = dce::run(&mut wg);
        if n > 0 {
            peaks = verify(&wg, &before, "dce")?;
        }
        record(&mut report, "dce", iteration, n, before, &peaks);
        total += n;

        let before = peaks.clone();
        let n = coalesce::run(&mut wg, cx, &mut report.transfer_seconds_saved);
        if n > 0 {
            peaks = verify(&wg, &before, "coalesce")?;
        }
        record(&mut report, "coalesce", iteration, n, before, &peaks);
        total += n;

        if level >= 2 {
            let before = peaks.clone();
            let mut stats = remat::RematStats::default();
            let n = remat::run(&mut wg, cx, &mut stats)?;
            if n > 0 {
                peaks = verify(&wg, &before, "remat")?;
            }
            report.bytes_freed += stats.bytes_freed;
            report.recompute_seconds_added += stats.recompute_seconds_added;
            record(&mut report, "remat", iteration, n, before, &peaks);
            total += n;
        }

        if total == 0 {
            quiesced = true;
            break;
        }
    }
    if !quiesced {
        return Err(Error::Sched(format!(
            "optimizer did not quiesce within {MAX_ITERS} iterations \
             ({} rewrites so far) — rewrite cycle suspected",
            report.rewrites()
        )));
    }
    report.peak_after = peaks.clone();
    report.nodes_after = wg.nodes.len();
    if level >= 2 {
        if let Some(budgets) = &cx.budgets {
            for (d, (&p, &b)) in peaks.iter().zip(budgets).enumerate() {
                if p > b {
                    return Err(Error::InfeasiblePlan(format!(
                        "post-opt static peak {p} B on device {d} exceeds budget {b} B \
                         (remat freed {} B; no profitable victim remains)",
                        report.bytes_freed
                    )));
                }
            }
        }
    }
    let (g, device_of, orig_of) = wg.to_graph()?;
    Ok(OptOutcome {
        graph: g,
        device_of,
        orig_of,
        report,
    })
}

/// Optimize a validated [`RowProgram`] (the serial/trainer entry point):
/// same engine, plus the semantic floor that every concrete task of the
/// input survives — the optimizer may drop pure debris, never work a
/// driver would execute.
pub fn optimize(program: &RowProgram, level: u8, cx: &OptContext) -> Result<(RowProgram, OptReport)> {
    let outcome = optimize_graph(program.graph(), level, cx)?;
    let optimized = RowProgram::new(outcome.graph)?;
    for node in program.graph().nodes() {
        if matches!(node.task, Task::Opaque | Task::Transfer) {
            continue;
        }
        if optimized.find_task(node.task).is_none() {
            return Err(Error::Sched(format!(
                "optimizer dropped concrete task {:?} ('{}')",
                node.task, node.label
            )));
        }
    }
    Ok((optimized, outcome.report))
}

fn record(
    report: &mut OptReport,
    pass: &'static str,
    iteration: usize,
    rewrites: usize,
    peak_before: Vec<u64>,
    peak_after: &[u64],
) {
    report.passes.push(PassOutcome {
        pass,
        iteration,
        rewrites,
        peak_before,
        peak_after: peak_after.to_vec(),
    });
}

/// Post-pass verification: peaks never rise, the rebuilt graph is valid,
/// and the analyzer stays error-free.  Returns the new peaks.
fn verify(wg: &WorkGraph, prev: &[u64], pass: &'static str) -> Result<Vec<u64>> {
    let peaks = wg.device_peaks();
    for (d, (&now, &was)) in peaks.iter().zip(prev).enumerate() {
        if now > was {
            return Err(Error::Sched(format!(
                "pass '{pass}' raised device {d} static peak {was} -> {now} B"
            )));
        }
    }
    let (g, _, _) = wg.to_graph()?;
    let lint = analysis::analyze(&g);
    if lint.has_errors() {
        return Err(Error::Sched(format!(
            "pass '{pass}' broke the analyzer: {}",
            lint.verdict()
        )));
    }
    Ok(peaks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rowir::graph::NodeKind;

    /// dead debris + duplicate transfers + a retain edge, all in one
    /// graph — every pass has work.
    fn composite() -> Graph {
        let mut g = Graph::new();
        let p = g.push_out(NodeKind::Row, "p", vec![], 100, 40);
        let t1 = g.push_task(NodeKind::Transfer, "x1", vec![p], 40, 40, Task::Transfer);
        let t2 = g.push_task(NodeKind::Transfer, "x2", vec![p], 40, 40, Task::Transfer);
        let _dead = g.push(NodeKind::Row, "dead", vec![], 9);
        let c1 = g.push(NodeKind::Row, "c1", vec![t1], 10);
        g.push(NodeKind::Barrier, "red", vec![t2, c1], 5);
        g
    }

    #[test]
    fn level_zero_is_the_identity() {
        let g = composite();
        let cx = OptContext::serial();
        let out = optimize_graph(&g, 0, &cx).unwrap();
        assert_eq!(out.graph.len(), g.len());
        assert_eq!(out.report.rewrites(), 0);
        assert_eq!(out.report.iterations, 0);
        let ids: Vec<Option<usize>> = (0..g.len()).map(Some).collect();
        assert_eq!(out.orig_of, ids, "identity provenance");
    }

    #[test]
    fn fixpoint_quiesces_and_never_raises_the_peak() {
        let g = composite();
        let cx = OptContext::serial();
        let before = analysis::static_peak(&g);
        let out = optimize_graph(&g, 2, &cx).unwrap();
        assert!(out.report.iterations <= MAX_ITERS);
        assert!(out.report.rewrites() >= 2, "dce + coalesce at least");
        assert!(analysis::static_peak(&out.graph) <= before);
        assert!(out.report.total_peak_after() <= out.report.total_peak_before());
        // re-optimizing the output is a no-op: a true fixpoint
        let again = optimize_graph(&out.graph, 2, &cx).unwrap();
        assert_eq!(again.report.rewrites(), 0);
        let json = out.report.to_json();
        assert!(crate::util::json::JsonValue::parse(&json).is_ok(), "{json}");
    }

    #[test]
    fn infeasible_budgets_are_a_typed_error() {
        let g = composite();
        let cx = OptContext::serial().with_budgets(vec![1]);
        match optimize_graph(&g, 2, &cx) {
            Err(Error::InfeasiblePlan(m)) => {
                assert!(m.contains("exceeds budget"), "{m}")
            }
            other => panic!("expected InfeasiblePlan, got {other:?}"),
        }
        // level 1 never judges budgets: same context, no error
        assert!(optimize_graph(&g, 1, &cx).is_ok());
    }

    #[test]
    fn concrete_tasks_survive_optimization() {
        let mut g = Graph::new();
        let a = g.push_task(NodeKind::Row, "a", vec![], 10, 4, Task::FpRow { seg: 0, row: 0 });
        g.push_task(NodeKind::Barrier, "red", vec![a], 3, 0, Task::ReduceA);
        let p = RowProgram::new(g).unwrap();
        let cx = OptContext::serial();
        let (opt, report) = optimize(&p, 2, &cx).unwrap();
        assert_eq!(opt.len(), p.len());
        assert_eq!(report.rewrites(), 0, "fully concrete programs are fixed points");
        assert!(opt.find_task(Task::ReduceA).is_some());
    }
}
