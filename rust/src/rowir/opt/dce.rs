//! Dead-node elimination — the rewrite form of the LIV001 dead-output
//! lint.
//!
//! Anchors are the nodes whose execution is observable: every node
//! carrying a concrete task (its handler writes host slots — deleting
//! one would change what a driver runs) plus the terminal
//! (highest-id) node, whose output is the step's result.  One
//! descending sweep marks every transitive dependency of an anchor;
//! whatever stays unmarked is `Opaque`/`Transfer` debris no observable
//! node ever reads — dangling transfers left behind by a remat rewire,
//! dead side fans in hand-built graphs — and is deleted.
//!
//! Deleting an unmarked node can only *lower* byte residency (its
//! working set and any parked output vanish; nothing else's lifetime
//! changes), so the pass trivially satisfies the pipeline's
//! never-raise-the-peak verification.

use crate::rowir::task::Task;

use super::WorkGraph;

/// Delete every non-anchor node with no transitive path to an anchor.
/// Returns the number of nodes removed.
pub(crate) fn run(wg: &mut WorkGraph) -> usize {
    let n = wg.nodes.len();
    if n == 0 {
        return 0;
    }
    let mut keep = vec![false; n];
    for (id, node) in wg.nodes.iter().enumerate() {
        if !matches!(node.task, Task::Opaque | Task::Transfer) {
            keep[id] = true;
        }
    }
    // the terminal node's output is the result even when Opaque
    // (hand-built/synthetic graphs carry no concrete tasks at all)
    keep[n - 1] = true;
    for id in (0..n).rev() {
        if keep[id] {
            for &d in &wg.nodes[id].deps {
                keep[d] = true;
            }
        }
    }
    let removed = keep.iter().filter(|&&k| !k).count();
    if removed > 0 {
        wg.retain(&keep);
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rowir::graph::{Graph, NodeKind};

    #[test]
    fn deletes_unreachable_debris_only() {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 5);
        let dead = g.push_out(NodeKind::Row, "dead", vec![], 7, 7);
        let _dead2 = g.push(NodeKind::Row, "dead.reader", vec![dead], 3);
        g.push(NodeKind::Barrier, "red", vec![a], 3);
        let mut wg = WorkGraph::from_graph(&g, None, 1);
        let peaks = wg.device_peaks();
        assert_eq!(run(&mut wg), 2, "the dead chain goes, its reader too");
        assert_eq!(wg.nodes.len(), 2);
        assert_eq!(wg.nodes[1].label, "red");
        assert!(wg.device_peaks()[0] <= peaks[0]);
        assert_eq!(run(&mut wg), 0, "idempotent at fixpoint");
    }

    #[test]
    fn concrete_tasks_are_anchors_even_as_sinks() {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 5);
        // a concrete sink that is not the terminal node: its handler has
        // observable effects, so it must survive
        g.push_task(
            NodeKind::Barrier,
            "reduce",
            vec![a],
            3,
            0,
            Task::ReduceA,
        );
        g.push(NodeKind::Row, "tail", vec![], 1);
        let mut wg = WorkGraph::from_graph(&g, None, 1);
        assert_eq!(run(&mut wg), 0, "anchor + terminal keep everything");
        assert_eq!(wg.nodes.len(), 3);
    }
}
