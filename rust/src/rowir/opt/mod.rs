//! `rowir::opt` — the fixpoint optimizer pipeline over a row program
//! (docs/ROWIR.md § Optimizer).
//!
//! Since PR 5 every lowering decision was final: nothing ever rewrote
//! the IR, so retained intermediates stayed retained even when
//! recomputing them would be cheaper than holding them.  This module
//! makes the lowering revisable with three **verified** rewrites that
//! run until quiescence ([`pipeline::optimize`]):
//!
//! * [`dce`] — dead-node elimination: `Opaque`/`Transfer` debris with no
//!   transitive path to a concrete task or the terminal node is deleted
//!   (the rewrite form of the LIV001 dead-output lint);
//! * [`coalesce`] — transfer coalescing/dedup: same-(producer,
//!   destination-device) [`Task::Transfer`] nodes merge into one copy,
//!   re-priced through the [`CostModel`], applied only when no device's
//!   static peak rises;
//! * [`remat`] — budget-driven rematerialization (Chen et al., sublinear
//!   memory cost): a parked `out_bytes` grant held to a distant last
//!   consumer is converted into a recompute subgraph cloned immediately
//!   before that consumer, victims picked greedily by bytes freed per
//!   modeled recompute second, until the per-device static peaks fit the
//!   budget or no profitable victim remains.
//!
//! Every pass is re-verified after it rewrites: the rebuilt graph passes
//! [`Graph::validate`], the static analyzer reports zero errors, and no
//! device's [`liveness::static_device_peaks`] bound rose.  Bit-identity
//! to the unoptimized program is structural, not empirical: rewrites
//! only clone pure (`Opaque`/`Transfer`) subgraphs or rewire a consumer
//! to an equivalent copy of the same payload — concrete tasks are never
//! duplicated (DET004 makes a duplicated concrete task an analyzer
//! *error*, and the handlers' write-once slots make re-running one
//! unsafe), and every f32 reduction stays inside a barrier task folding
//! rows in fixed serial order, so dependency rewiring never changes
//! arithmetic.
//!
//! The passes rewrite a [`WorkGraph`] — a mutable mirror of the IR with
//! per-node device assignment and input-graph provenance — because
//! [`Graph`] is deliberately append-only (drivers never mutate a
//! program); [`WorkGraph::to_graph`] rebuilds a validated graph after
//! each rewriting pass.

pub(crate) mod coalesce;
pub(crate) mod dce;
pub(crate) mod pipeline;
pub(crate) mod remat;

pub use pipeline::{optimize, optimize_graph, OptOutcome, OptReport, PassOutcome, MAX_ITERS};

use crate::costmodel::CostModel;
use crate::memory::DeviceModel;

use super::graph::{Graph, NodeId, NodeKind};
use super::task::Task;

/// Everything the passes need beyond the graph itself: the device
/// context (assignment + count — serial callers use one device), the
/// optional per-device byte budgets the remat pass drives toward, and
/// the [`CostModel`] that prices recompute subgraphs and merged
/// transfers.
#[derive(Debug, Clone)]
pub struct OptContext {
    /// Device-lane count (`>= 1`; `1` for serial programs).
    pub devices: usize,
    /// Device per node of the *input* graph (`None` ⇒ everything on
    /// device 0).  Clones inherit the device of the node they clone.
    pub device_of: Option<Vec<usize>>,
    /// Per-device static-peak targets for [`remat`].  `None` means
    /// best-effort: reduce peaks while profitable, never declare
    /// infeasibility.  `Some` at level ≥ 2 turns "does not fit after the
    /// fixpoint" into a typed [`Error::InfeasiblePlan`](crate::error::Error).
    pub budgets: Option<Vec<u64>>,
    /// Prices recompute-vs-retain ([`CostModel::recompute_seconds`]) and
    /// coalesced transfers ([`CostModel::transfer_seconds`]).
    pub cost: CostModel,
}

impl OptContext {
    /// Single-device context with the stock analytic cost model — what
    /// the serial trainer path and the CLI use.
    pub fn serial() -> OptContext {
        let dev = DeviceModel::rtx3090();
        let link = dev.pcie_bytes_per_sec;
        OptContext {
            devices: 1,
            device_of: None,
            budgets: None,
            cost: CostModel::analytic(&[dev], link),
        }
    }

    /// Install per-device peak budgets (see [`OptContext::budgets`]).
    pub fn with_budgets(mut self, budgets: Vec<u64>) -> OptContext {
        self.budgets = Some(budgets);
        self
    }
}

/// One node of the optimizer's mutable graph mirror.
#[derive(Debug, Clone)]
pub(crate) struct WorkNode {
    pub kind: NodeKind,
    pub label: String,
    /// Sorted + deduplicated, each `<` this node's index — the passes
    /// maintain the [`Graph`] invariants at every step.
    pub deps: Vec<usize>,
    pub task: Task,
    pub est_bytes: u64,
    pub out_bytes: u64,
    /// Device lane (0 for serial programs); clones inherit it.
    pub device: usize,
    /// Node id in the optimizer's *input* graph; `None` for synthesized
    /// clones — the provenance `ShardPlan::optimize` composes with its
    /// own `orig` map.
    pub orig: Option<NodeId>,
}

/// Mutable mirror of a row graph: same nodes, same invariants (ids
/// topological, deps sorted/deduped, labels unique), plus device
/// assignment, provenance and a fresh-label counter for remat clones.
#[derive(Debug, Clone)]
pub(crate) struct WorkGraph {
    pub nodes: Vec<WorkNode>,
    pub devices: usize,
    /// Monotone counter making `remat.<k>.<label>` clone labels unique
    /// across rewrites (the same victim may be cloned more than once).
    fresh: usize,
}

impl WorkGraph {
    pub fn from_graph(graph: &Graph, device_of: Option<&[usize]>, devices: usize) -> WorkGraph {
        let nodes = graph
            .nodes()
            .iter()
            .enumerate()
            .map(|(id, n)| WorkNode {
                kind: n.kind,
                label: n.label.clone(),
                deps: n.deps.clone(),
                task: n.task,
                est_bytes: n.est_bytes,
                out_bytes: n.out_bytes,
                device: device_of.map_or(0, |d| d[id]),
                orig: Some(id),
            })
            .collect();
        WorkGraph {
            nodes,
            devices: devices.max(1),
            fresh: 0,
        }
    }

    /// Rebuild a validated [`Graph`] plus the device assignment and the
    /// input-graph provenance of every surviving node.
    pub fn to_graph(&self) -> crate::error::Result<(Graph, Vec<usize>, Vec<Option<NodeId>>)> {
        let mut g = Graph::new();
        for node in &self.nodes {
            g.push_task(
                node.kind,
                node.label.clone(),
                node.deps.clone(),
                node.est_bytes,
                node.out_bytes,
                node.task,
            );
        }
        g.validate()?;
        Ok((
            g,
            self.nodes.iter().map(|n| n.device).collect(),
            self.nodes.iter().map(|n| n.orig).collect(),
        ))
    }

    /// Per-device static peaks of the serial-order byte ledger —
    /// event-for-event the sweep of
    /// [`liveness::static_device_peaks`](crate::rowir::analysis::static_device_peaks),
    /// so a pass can price a trial rewrite without rebuilding a [`Graph`].
    pub fn device_peaks(&self) -> Vec<u64> {
        let n = self.nodes.len();
        let mut left = vec![0usize; n];
        for node in &self.nodes {
            for &d in &node.deps {
                left[d] += 1;
            }
        }
        let mut live = vec![0u64; self.devices];
        let mut peak = vec![0u64; self.devices];
        for (id, node) in self.nodes.iter().enumerate() {
            let dev = node.device;
            peak[dev] = peak[dev].max(live[dev] + node.est_bytes);
            if left[id] > 0 && node.out_bytes > 0 {
                live[dev] += node.out_bytes;
                peak[dev] = peak[dev].max(live[dev]);
            }
            for &dep in &node.deps {
                left[dep] -= 1;
                if left[dep] == 0 && self.nodes[dep].out_bytes > 0 {
                    live[self.nodes[dep].device] -= self.nodes[dep].out_bytes;
                }
            }
        }
        peak
    }

    /// Highest-id consumer per node (`None` when nothing reads it) —
    /// where a parked output dies under the serial schedule.
    pub fn last_use(&self) -> Vec<Option<usize>> {
        let mut last = vec![None; self.nodes.len()];
        for (id, node) in self.nodes.iter().enumerate() {
            for &d in &node.deps {
                last[d] = Some(id);
            }
        }
        last
    }

    /// Drop every node with `keep[id] == false`, remapping the survivors'
    /// deps.  Callers must pass a dependency-closed mask (a kept node's
    /// deps are kept) — both passes that delete do: DCE's mark set is
    /// ancestor-closed, and coalesce redirects every consumer before it
    /// deletes the duplicate.
    pub fn retain(&mut self, keep: &[bool]) {
        debug_assert_eq!(keep.len(), self.nodes.len());
        let mut remap = vec![usize::MAX; self.nodes.len()];
        let mut next = 0usize;
        for (id, &k) in keep.iter().enumerate() {
            if k {
                remap[id] = next;
                next += 1;
            }
        }
        let old = std::mem::take(&mut self.nodes);
        for (id, mut node) in old.into_iter().enumerate() {
            if !keep[id] {
                continue;
            }
            for d in node.deps.iter_mut() {
                debug_assert_ne!(remap[*d], usize::MAX, "kept node depends on a deleted one");
                *d = remap[*d];
            }
            // the remap is monotone, so sortedness survives
            self.nodes.push(node);
        }
    }

    /// Next value of the clone-label counter (`remat.<k>.<label>`).
    pub fn next_fresh(&mut self) -> usize {
        let k = self.fresh;
        self.fresh += 1;
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rowir::analysis;

    fn fan() -> Graph {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 100, 40);
        let b = g.push_out(NodeKind::Row, "b", vec![], 100, 40);
        g.push(NodeKind::Barrier, "red", vec![a, b], 80);
        g
    }

    #[test]
    fn roundtrip_preserves_the_graph_and_provenance() {
        let g = fan();
        let wg = WorkGraph::from_graph(&g, None, 1);
        let (g2, dev, orig) = wg.to_graph().unwrap();
        assert_eq!(g2.len(), g.len());
        assert_eq!(dev, vec![0, 0, 0]);
        assert_eq!(orig, vec![Some(0), Some(1), Some(2)]);
        for (a, b) in g.nodes().iter().zip(g2.nodes()) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.deps, b.deps);
            assert_eq!(a.task, b.task);
        }
    }

    #[test]
    fn device_peaks_match_the_liveness_sweep() {
        let g = fan();
        // serial
        let wg = WorkGraph::from_graph(&g, None, 1);
        assert_eq!(wg.device_peaks(), vec![analysis::static_peak(&g)]);
        // split: b on device 1
        let dev = vec![0usize, 1, 0];
        let wg = WorkGraph::from_graph(&g, Some(&dev), 2);
        assert_eq!(
            wg.device_peaks(),
            analysis::static_device_peaks(&g, &dev, 2)
        );
    }

    #[test]
    fn retain_remaps_deps() {
        let mut g = Graph::new();
        let a = g.push_out(NodeKind::Row, "a", vec![], 10, 5);
        let _dead = g.push(NodeKind::Row, "dead", vec![], 7);
        g.push(NodeKind::Barrier, "red", vec![a], 3);
        let mut wg = WorkGraph::from_graph(&g, None, 1);
        wg.retain(&[true, false, true]);
        assert_eq!(wg.nodes.len(), 2);
        assert_eq!(wg.nodes[1].label, "red");
        assert_eq!(wg.nodes[1].deps, vec![0]);
        assert_eq!(wg.nodes[1].orig, Some(2), "provenance survives the remap");
        assert!(wg.to_graph().is_ok());
    }

    #[test]
    fn last_use_is_the_highest_consumer() {
        let g = fan();
        let wg = WorkGraph::from_graph(&g, None, 1);
        assert_eq!(wg.last_use(), vec![Some(2), Some(2), None]);
    }
}
